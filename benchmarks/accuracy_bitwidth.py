"""Paper §II precision analysis: softmax is precision-insensitive — a 7-9
bit fixed-point LUT preserves model accuracy (CNEWS 8b / MRPC 9b / CoLA 7b).

Protocol (the paper's, at laptop scale): train a small bidirectional
attention classifier with EXACT softmax on an attention-critical retrieval
task (induction: find the repeat of the cue token, report its successor),
then swap the attention softmax for the STAR engine at decreasing bitwidths.
The claim reproduces as: accuracy(calibrated 7-9 bit) ~ accuracy(exact),
collapsing at very low bitwidths where attention can no longer stay sharp.

The fault sweep (DESIGN.md §9) extends the same protocol past quantization:
for each calibrated format it scans stuck-cell rate x conductance sigma
(seeded :class:`~repro.ops.FaultModel` realizations on the same trained
model) and emits accuracy-vs-fault curves — ``--json`` writes them next to
the bitwidth results::

    python benchmarks/accuracy_bitwidth.py --json out.json \
        --fault-sigma 0,0.1,0.3 --fault-stuck-rate 0,0.02,0.1
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.core.fixedpoint import FixedPointFormat

D, H, LAYERS, VOCAB, CLASSES, SEQ = 64, 4, 2, 32, 8, 32


def gen_data(n, seed):
    """Induction retrieval: toks[0] is a cue; it reappears once at a random
    position p; the label is toks[p+1] % CLASSES."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(CLASSES, VOCAB, (n, SEQ)).astype(np.int32)  # filler
    cue = rng.integers(CLASSES, VOCAB, n)
    p = rng.integers(2, SEQ - 1, n)
    ans = rng.integers(0, CLASSES, n)
    rows = np.arange(n)
    toks[rows, 0] = cue
    toks[rows, p] = cue
    toks[rows, p + 1] = ans  # answer tokens live in [0, CLASSES)
    return jnp.asarray(toks), jnp.asarray(ans)


def init_params(key):
    ks = jax.random.split(key, 3 + LAYERS)
    p = {
        "emb": jax.random.normal(ks[0], (VOCAB, D)) * 0.1,
        "pos": jax.random.normal(ks[1], (SEQ, D)) * 0.1,
        "head": jax.random.normal(ks[2], (D, CLASSES)) * 0.1,
        "layers": [],
    }
    for i in range(LAYERS):
        k1, k2, k3, k4, k5, k6 = jax.random.split(ks[3 + i], 6)
        p["layers"].append({
            "wq": jax.random.normal(k1, (D, D)) * D ** -0.5,
            "wk": jax.random.normal(k2, (D, D)) * D ** -0.5,
            "wv": jax.random.normal(k3, (D, D)) * D ** -0.5,
            "wo": jax.random.normal(k4, (D, D)) * D ** -0.5,
            "w1": jax.random.normal(k5, (D, 2 * D)) * D ** -0.5,
            "w2": jax.random.normal(k6, (2 * D, D)) * (2 * D) ** -0.5,
        })
    return p


def _norm(x):
    return x / (jnp.linalg.norm(x, axis=-1, keepdims=True) / jnp.sqrt(D) + 1e-6)


def forward(p, toks, softmax: ops.SoftmaxSpec):
    spec = ops.AttentionSpec(impl="reference", softmax=softmax)  # bidirectional
    x = p["emb"][toks] + p["pos"][None]
    for lp in p["layers"]:
        xn = _norm(x)
        q = (xn @ lp["wq"]).reshape(*xn.shape[:2], H, D // H)
        k = (xn @ lp["wk"]).reshape(*xn.shape[:2], H, D // H)
        v = (xn @ lp["wv"]).reshape(*xn.shape[:2], H, D // H)
        a = ops.attention(q, k, v, spec)
        x = x + a.reshape(xn.shape) @ lp["wo"]
        x = x + jax.nn.gelu(_norm(x) @ lp["w1"]) @ lp["w2"]
    return x[:, 0] @ p["head"]  # classify from the cue position


def train(steps=400, lr=2e-3, seed=0):
    key = jax.random.PRNGKey(seed)
    p = init_params(key)
    exact = ops.SoftmaxSpec(kind="exact")
    mom = jax.tree.map(jnp.zeros_like, p)
    vel = jax.tree.map(jnp.zeros_like, p)

    def loss_fn(p, toks, cls):
        logits = forward(p, toks, exact)
        return jnp.mean(-jax.nn.log_softmax(logits)[jnp.arange(len(cls)), cls])

    @jax.jit
    def step(p, mom, vel, toks, cls, t):
        l, g = jax.value_and_grad(loss_fn)(p, toks, cls)
        mom = jax.tree.map(lambda m, gw: 0.9 * m + 0.1 * gw, mom, g)
        vel = jax.tree.map(lambda v, gw: 0.99 * v + 0.01 * gw * gw, vel, g)
        c1 = 1 - 0.9 ** t
        c2 = 1 - 0.99 ** t
        p = jax.tree.map(
            lambda w, m, v: w - lr * (m / c1) / (jnp.sqrt(v / c2) + 1e-8),
            p, mom, vel,
        )
        return p, mom, vel, l

    for s in range(steps):
        toks, cls = gen_data(128, seed=1000 + s)
        p, mom, vel, l = step(p, mom, vel, toks, cls, jnp.asarray(s + 1.0))
    return p


def evaluate(p, softmax: ops.SoftmaxSpec, seed=9) -> float:
    toks, cls = gen_data(1024, seed)
    pred = jnp.argmax(forward(p, toks, softmax), -1)
    return float(jnp.mean(pred == cls))


SWEEPS = [
    ("mrpc_9b", FixedPointFormat(6, 3)),
    ("cnews_8b", FixedPointFormat(6, 2)),
    ("cola_7b", FixedPointFormat(5, 2)),
    ("6b", FixedPointFormat(5, 1)),
    ("5b", FixedPointFormat(4, 1)),
    ("4b", FixedPointFormat(3, 1)),
    ("3b", FixedPointFormat(2, 1)),
    ("2b", FixedPointFormat(1, 1)),
]

# calibrated formats the fault sweep stresses (>= 2, per the paper's own
# per-dataset calibration points)
FAULT_FORMATS = [
    ("cnews_8b", FixedPointFormat(6, 2)),
    ("cola_7b", FixedPointFormat(5, 2)),
]


def run(steps: int = 400) -> Tuple[Dict[str, float], dict]:
    p = train(steps=steps)
    results = {"exact": evaluate(p, ops.SoftmaxSpec(kind="exact"))}
    for name, fmt in SWEEPS:
        results[name] = evaluate(p, ops.SoftmaxSpec(kind="star", precision=fmt))
    return results, p


def fault_sweep(
    p,
    sigmas: Sequence[float],
    stuck_rates: Sequence[float],
    seed: int = 0,
) -> List[dict]:
    """Accuracy over the fault grid (stuck rate x sigma) per format.

    Stuck cells split evenly between G_on and G_off; each grid point is one
    seeded realization, so re-runs reproduce the same curve exactly.
    """
    curves: List[dict] = []
    for name, fmt in FAULT_FORMATS:
        for sigma in sigmas:
            for rate in stuck_rates:
                fault = ops.FaultModel(
                    g_sigma=sigma,
                    stuck_on_rate=rate / 2,
                    stuck_off_rate=rate / 2,
                    seed=seed,
                )
                spec = ops.SoftmaxSpec(kind="star", precision=fmt, fault=fault)
                curves.append({
                    "format": name,
                    "g_sigma": sigma,
                    "stuck_rate": rate,
                    "accuracy": evaluate(p, spec),
                    "spec": ops.spec_json(spec),
                })
    return curves


def _float_list(text: str) -> List[float]:
    return [float(v) for v in text.split(",") if v.strip()]


def main(argv: Sequence[str] | None = None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write bitwidth results + fault curves as JSON")
    ap.add_argument("--fault-sigma", type=_float_list, default=[0.0, 0.1, 0.3],
                    metavar="S0,S1,...",
                    help="lognormal conductance sigmas for the fault sweep")
    ap.add_argument("--fault-stuck-rate", type=_float_list,
                    default=[0.0, 0.02, 0.1], metavar="R0,R1,...",
                    help="total stuck-cell rates (split evenly on/off)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="FaultModel realization seed")
    ap.add_argument("--steps", type=int, default=400,
                    help="training steps before the sweeps")
    args = ap.parse_args(argv)

    r, p = run(steps=args.steps)
    for k, v in r.items():
        print(f"accuracy_bitwidth_{k},{v*100:.1f},acc_pct")

    curves = None
    if args.json:
        curves = fault_sweep(
            p, args.fault_sigma, args.fault_stuck_rate, seed=args.fault_seed
        )
        for c in curves:
            print(
                f"accuracy_fault_{c['format']}_s{c['g_sigma']}_r"
                f"{c['stuck_rate']},{c['accuracy']*100:.1f},acc_pct"
            )
        with open(args.json, "w") as f:
            json.dump({"bitwidth": r, "fault_curves": curves}, f, indent=2)
        print(f"wrote {args.json}")

    assert r["exact"] > 0.9, f"training failed to learn the task: {r['exact']}"
    # the paper's claim: calibrated 7-9 bit formats preserve accuracy
    for k in ("cola_7b", "cnews_8b", "mrpc_9b"):
        assert r[k] >= r["exact"] - 0.02, (k, r[k], r["exact"])
    # and extreme truncation eventually hurts
    assert r["2b"] < r["exact"] - 0.02, ("2-bit should degrade", r["2b"])
    return r if curves is None else (r, curves)


if __name__ == "__main__":
    main()
