"""Benchmark harness — one entry per paper table/figure.

Prints ``name,value,derived`` CSV lines.  Run:
    PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        accuracy_bitwidth,
        fig3_efficiency,
        kernel_bench,
        serve_throughput,
        softmax_fraction,
        table1_area_power,
    )

    suites = [
        ("softmax_fraction (paper §I motivation)", softmax_fraction.main),
        ("table1_area_power (paper Table I)", table1_area_power.main),
        ("fig3_efficiency (paper Fig 3)", fig3_efficiency.main),
        ("accuracy_bitwidth (paper §II precision)", accuracy_bitwidth.main),
        ("kernel_bench (kernels)", kernel_bench.main),
        ("serve_throughput (continuous batching)", serve_throughput.main),
    ]
    failures = []
    for name, fn in suites:
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# ({time.time() - t0:.1f}s)", flush=True)
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)
    print("# all benchmarks passed")


if __name__ == "__main__":
    main()
