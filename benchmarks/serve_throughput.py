"""Serving throughput: lockstep batching vs continuous batching.

A Poisson arrival trace of mixed-length requests is served two ways:

* **lockstep** — requests are grouped into fixed batches of ``slots`` in
  arrival order; each batch prefills together (prompts right-padded to the
  batch max) and decodes for the batch max generation budget.  Every
  request pays for the longest member of its batch, and a batch cannot
  start until its last member has arrived.
* **continuous** — the slot-pool engine admits each request as it arrives
  (1 engine tick = 1 time unit of the trace) and retires it the moment its
  own budget is done, so lanes never idle on a co-tenant's schedule.

Three views, printed as ``name,value,derived`` CSV (benchmarks/run.py
idiom):

1. ``decode_steps`` — pool-wide decode steps executed (device work; both
   engines step the same [slots]-wide jitted decode, so the ratio is the
   device-level *decode* speedup, independent of host dispatch noise).
   Prefill passes are reported separately on each line: continuous pays
   one batch-1 prefill per request, lockstep one batched prefill per
   group — they are different-shaped programs, so they are counted, not
   folded into the ratio.
2. ``makespan`` — completion time in trace units (1 decode step = 1 unit,
   prefill = 1 unit), *including* arrival waits: the latency picture.
3. ``toks_per_s`` — measured wall-clock useful tokens/sec.  CPU smoke
   numbers: host Python dispatch dominates at this scale (the continuous
   engine prefills request-by-request), so treat the wall numbers as an
   end-to-end liveness check and the step/makespan columns as the result.

    PYTHONPATH=src python -m benchmarks.serve_throughput
"""

from __future__ import annotations

import time

import numpy as np


def make_trace(n_requests: int, rng: np.random.Generator, *, rate: float = 0.8):
    """Poisson arrivals (exp inter-arrival, ``rate`` per tick) of requests
    with uniformly mixed prompt lengths and generation budgets."""
    t = 0.0
    trace = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        trace.append({
            "arrival": t,
            "prompt_len": int(rng.integers(4, 24)),
            "gen": int(rng.integers(4, 16)),
        })
    return trace


def run_lockstep(cfg, params, trace, prompts, slots, max_len):
    import jax.numpy as jnp

    from repro.serve.engine import ServeConfig, ServeEngine

    eng = ServeEngine(cfg, params, ServeConfig(max_len=max_len, temperature=0.0))
    useful = steps = prefills = 0
    clock = 0.0  # trace-time: batch starts after its last arrival
    t0 = time.perf_counter()
    for i in range(0, len(trace), slots):
        batch = trace[i:i + slots]
        bp = prompts[i:i + slots]
        plen = max(r["prompt_len"] for r in batch)
        gen = max(r["gen"] for r in batch)
        # right-pad prompts to the batch max (lockstep needs one shape)
        mat = np.zeros((len(batch), plen), np.int32)
        for j, p in enumerate(bp):
            mat[j, :len(p)] = p
        eng.generate(jnp.asarray(mat), gen)
        useful += sum(r["gen"] for r in batch)
        steps += gen - 1  # token 0 of each batch comes from the prefill
        prefills += 1
        clock = max(clock, max(r["arrival"] for r in batch)) + 1 + (gen - 1)
    dt = time.perf_counter() - t0
    return {"tokens": useful, "steps": steps, "prefills": prefills,
            "makespan": clock, "wall": dt}


def run_continuous(cfg, params, trace, prompts, slots, max_len):
    from repro.serve.engine import ContinuousBatchingEngine, ContinuousConfig

    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=slots, max_len=max_len))
    useful = 0
    t0 = time.perf_counter()
    i = 0
    tick = 0
    while i < len(trace) or not eng.scheduler.done():
        while i < len(trace) and trace[i]["arrival"] <= tick:
            eng.submit(prompts[i], trace[i]["gen"],
                       arrival_time=trace[i]["arrival"])
            useful += trace[i]["gen"]
            i += 1
        eng.step()
        tick += 1
    dt = time.perf_counter() - t0
    return {"tokens": useful, "steps": eng.ticks, "prefills": len(trace),
            "makespan": float(tick), "wall": dt,
            "util": useful / max(eng.ticks * slots, 1)}


def main(n_requests: int = 12, slots: int = 4):
    import jax

    from repro.configs import get_smoke_config
    from repro.models.param import materialize
    from repro.models.registry import build_model

    cfg = get_smoke_config("granite_8b")
    model = build_model(cfg)
    params = materialize(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    trace = make_trace(n_requests, rng)
    prompts = [rng.integers(0, cfg.vocab_size, (r["prompt_len"],)).astype(np.int32)
               for r in trace]
    max_len = 24 + 16 + 8  # prompt + gen + headroom

    lk = run_lockstep(cfg, params, trace, prompts, slots, max_len)
    print(f"serve_lockstep_decode_steps,{lk['steps']},"
          f"prefills={lk['prefills']} makespan={lk['makespan']:.0f} "
          f"toks_per_s={lk['tokens'] / lk['wall']:.1f}")

    cb = run_continuous(cfg, params, trace, prompts, slots, max_len)
    print(f"serve_continuous_decode_steps,{cb['steps']},"
          f"prefills={cb['prefills']} makespan={cb['makespan']:.0f} "
          f"toks_per_s={cb['tokens'] / cb['wall']:.1f} "
          f"slot_util={cb['util']:.2f}")

    print(f"serve_continuous_step_speedup,{lk['steps'] / cb['steps']:.2f}x,"
          f"device_decode_work requests={n_requests} slots={slots}")
    print(f"serve_continuous_makespan_speedup,{lk['makespan'] / cb['makespan']:.2f}x,"
          f"trace_time_incl_arrivals")
    return True


if __name__ == "__main__":
    main()
