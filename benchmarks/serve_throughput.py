"""Serving throughput: lockstep vs continuous vs paged-KV continuous.

A Poisson arrival trace of mixed-length requests is served three ways:

* **lockstep** — requests are grouped into fixed batches of ``slots`` in
  arrival order; each batch prefills together (prompts right-padded to the
  batch max) and decodes for the batch max generation budget.  Every
  request pays for the longest member of its batch, and a batch cannot
  start until its last member has arrived.
* **continuous** — the slot-pool engine admits each request as it arrives
  (1 engine tick = 1 time unit of the trace) and retires it the moment its
  own budget is done, so lanes never idle on a co-tenant's schedule.
* **paged** — the same continuous engine over the block-pool KV cache
  (DESIGN.md §8): memory is allocated in ``kv_block_size``-token blocks as
  requests grow, so peak KV bytes track *live tokens* instead of
  ``slots * max_len``.  Greedy decode is token-identical to the dense
  path, so steps/makespan match and the delta is purely memory.

Views, printed as ``name,value,derived`` CSV (benchmarks/run.py idiom):

1. ``decode_steps`` — pool-wide decode steps executed (device work).
   Prefill passes are reported separately on each line.
2. ``makespan`` — completion time in trace units (1 decode step = 1 unit,
   prefill = 1 unit), *including* arrival waits: the latency picture.
3. ``toks_per_s`` — measured wall-clock useful tokens/sec (CPU smoke:
   host dispatch dominates; treat as a liveness check).
4. ``peak_kv_bytes`` — what an allocator must pin: the dense engines pin
   their full pool; the paged engine pins its peak allocated blocks.
   Per-tick block-pool occupancy lands in the ``--json`` record so
   BENCH_*.json can track memory as well as speed.
5. ``ttft`` / ``itl`` — per-request latency percentiles (p50/p95/p99,
   wall seconds) sourced from the engine's obs histograms
   (``serve.ttft_s`` / ``serve.itl_s`` / ``serve.queue_wait_s``,
   DESIGN.md §10), printed for the continuous engines and embedded in
   the ``--json`` record under ``latency``.
6. ``prefix_tokens_saved`` — a second, shared-prefix trace (every prompt
   opens with the same 16 tokens) served by the paged engine with
   ``prefix_cache`` + chunked prefill (DESIGN.md §12).  Reports the
   fraction of prefill tokens skipped via the radix trie (asserted
   ≥ 30%), token parity against the uncached paged run, and makespan
   parity on the original *disjoint* trace (the cache must not slow
   down traffic that cannot share).  Lands in ``--json`` under
   ``prefix``.

    PYTHONPATH=src python -m benchmarks.serve_throughput [--json out.json]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks._timing import Stopwatch


def make_trace(n_requests: int, rng: np.random.Generator, *, rate: float = 0.8):
    """Poisson arrivals (exp inter-arrival, ``rate`` per tick) of requests
    with uniformly mixed prompt lengths and generation budgets."""
    t = 0.0
    trace = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        trace.append({
            "arrival": t,
            "prompt_len": int(rng.integers(4, 24)),
            "gen": int(rng.integers(4, 16)),
        })
    return trace


def _latency_percentiles(eng):
    """TTFT / ITL / queue-wait percentiles (wall seconds) read from the
    engine's obs histograms (DESIGN.md §10) — the benchmark reports what
    the metrics layer measured, not a separately hand-rolled list."""
    out = {}
    for name, key in (("serve.ttft_s", "ttft"), ("serve.itl_s", "itl"),
                      ("serve.queue_wait_s", "queue_wait")):
        h = eng.metrics.histogram(name)
        out[key] = {"count": h.count(), "p50": h.percentile(50),
                    "p95": h.percentile(95), "p99": h.percentile(99)}
    return out


def run_lockstep(cfg, params, trace, prompts, slots, max_len):
    import jax.numpy as jnp

    from repro.serve.engine import ServeConfig, ServeEngine

    eng = ServeEngine(cfg, params, ServeConfig(max_len=max_len, temperature=0.0))
    useful = steps = prefills = 0
    clock = 0.0  # trace-time: batch starts after its last arrival
    with Stopwatch() as sw:
        for i in range(0, len(trace), slots):
            batch = trace[i:i + slots]
            bp = prompts[i:i + slots]
            plen = max(r["prompt_len"] for r in batch)
            gen = max(r["gen"] for r in batch)
            # right-pad prompts to the batch max (lockstep needs one shape)
            mat = np.zeros((len(batch), plen), np.int32)
            for j, p in enumerate(bp):
                mat[j, :len(p)] = p
            eng.generate(jnp.asarray(mat), gen)
            useful += sum(r["gen"] for r in batch)
            steps += gen - 1  # token 0 of each batch comes from the prefill
            prefills += 1
            clock = max(clock, max(r["arrival"] for r in batch)) + 1 + (gen - 1)
    return {"engine": "lockstep", "tokens": useful, "steps": steps,
            "prefills": prefills, "makespan": clock, "wall": sw.seconds}


def run_continuous(cfg, params, trace, prompts, slots, max_len, *,
                   kv_layout="dense", kv_block_size=16, kv_pool_blocks=None,
                   prefix_cache=False, prefill_chunk_tokens=None,
                   kv_dtype="fp32"):
    from repro.serve.engine import ContinuousBatchingEngine, ContinuousConfig

    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=slots, max_len=max_len,
                         kv_layout=kv_layout, kv_block_size=kv_block_size,
                         kv_pool_blocks=kv_pool_blocks,
                         prefix_cache=prefix_cache,
                         prefill_chunk_tokens=prefill_chunk_tokens,
                         kv_dtype=kv_dtype))
    useful = 0
    occupancy = []  # per-tick allocated blocks (paged) for the JSON record
    outputs = {}
    i = 0
    tick = 0
    with Stopwatch() as sw:
        while i < len(trace) or not eng.scheduler.done():
            while i < len(trace) and trace[i]["arrival"] <= tick:
                eng.submit(prompts[i], trace[i]["gen"],
                           arrival_time=trace[i]["arrival"])
                useful += trace[i]["gen"]
                i += 1
            eng.step()
            if eng.kv_layout == "paged":
                occupancy.append(eng.block_pool.used_blocks)
            tick += 1
    outputs.update(eng.scheduler.finished)
    st = eng.kv_stats()
    # each preemption re-admission runs one extra prefill pass
    prefills = len(trace) + st.get("preemptions", 0)
    out = {"engine": f"continuous[{eng.kv_layout}]", "tokens": useful,
           "steps": eng.ticks, "prefills": prefills,
           "makespan": float(tick), "wall": sw.seconds,
           "util": useful / max(eng.ticks * slots, 1),
           "peak_kv_bytes": st["peak_kv_bytes"],
           "kv_bytes_capacity": st["kv_bytes_capacity"],
           "latency": _latency_percentiles(eng),
           "outputs": outputs}
    if eng.kv_layout == "paged":
        out["block_occupancy_per_tick"] = occupancy
        out["peak_used_blocks"] = st["peak_used_blocks"]
        out["total_blocks"] = st["total_blocks"]
        out["preemptions"] = st["preemptions"]
        out["kv_block_size"] = kv_block_size
        # counted pool-read traffic for the resolved paged backend
        # (DESIGN.md §11): the gather adapters pay the full table window,
        # pallas_paged pays live pages only
        out["gather_bytes_per_token"] = st["gather_bytes_per_token"]
        out["prefix"] = st.get("prefix")
        # quantized-layout accounting (DESIGN.md §13): amortized storage
        # cost of one cached token, scale pages included
        out["kv_dtype"] = st["kv_dtype"]
        out["kv_bytes_per_token"] = st["kv_bytes_per_token"]
    return out


def main(n_requests: int = 12, slots: int = 4, kv_block_size: int = 16,
         json_path: str | None = None,
         kv_dtypes: tuple = ("fp32", "int8", "fp8_e4m3")):
    import jax

    from repro.configs import get_smoke_config
    from repro.models.param import materialize
    from repro.models.registry import build_model

    cfg = get_smoke_config("granite_8b")
    model = build_model(cfg)
    params = materialize(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    trace = make_trace(n_requests, rng)
    prompts = [rng.integers(0, cfg.vocab_size, (r["prompt_len"],)).astype(np.int32)
               for r in trace]
    max_len = 24 + 16 + 8  # prompt + gen + headroom

    lk = run_lockstep(cfg, params, trace, prompts, slots, max_len)
    print(f"serve_lockstep_decode_steps,{lk['steps']},"
          f"prefills={lk['prefills']} makespan={lk['makespan']:.0f} "
          f"toks_per_s={lk['tokens'] / lk['wall']:.1f}")

    cb = run_continuous(cfg, params, trace, prompts, slots, max_len)
    print(f"serve_continuous_decode_steps,{cb['steps']},"
          f"prefills={cb['prefills']} makespan={cb['makespan']:.0f} "
          f"toks_per_s={cb['tokens'] / cb['wall']:.1f} "
          f"slot_util={cb['util']:.2f}")
    for key in ("ttft", "itl"):
        p = cb["latency"][key]
        print(f"serve_continuous_{key}_p50_ms,{p['p50'] * 1e3:.2f},"
              f"p95={p['p95'] * 1e3:.2f} p99={p['p99'] * 1e3:.2f} "
              f"n={p['count']} source=obs_histograms")

    pg = run_continuous(cfg, params, trace, prompts, slots, max_len,
                        kv_layout="paged", kv_block_size=kv_block_size)
    print(f"serve_paged_decode_steps,{pg['steps']},"
          f"prefills={pg['prefills']} makespan={pg['makespan']:.0f} "
          f"toks_per_s={pg['tokens'] / pg['wall']:.1f} "
          f"peak_blocks={pg['peak_used_blocks']}/{pg['total_blocks']} "
          f"preemptions={pg['preemptions']}")
    print(f"serve_paged_gather_bytes_per_token,"
          f"{pg['gather_bytes_per_token']:.0f},"
          f"counted_pool_read_traffic source=kv_stats")

    print(f"serve_continuous_step_speedup,{lk['steps'] / cb['steps']:.2f}x,"
          f"device_decode_work requests={n_requests} slots={slots}")
    print(f"serve_continuous_makespan_speedup,"
          f"{lk['makespan'] / cb['makespan']:.2f}x,trace_time_incl_arrivals")
    # the paged deltas: memory strictly below dense at parity makespan.
    # Parity is a hard invariant (DESIGN.md §8) — fail loudly, don't just
    # print, so scripted runs catch a paged-vs-dense divergence.
    parity = all(pg["outputs"][u] == cb["outputs"][u] for u in cb["outputs"])
    assert parity, "paged greedy output diverged from the dense engine"
    print(f"serve_paged_kv_bytes_vs_dense,"
          f"{pg['peak_kv_bytes'] / cb['peak_kv_bytes']:.2f}x,"
          f"peak {pg['peak_kv_bytes']} vs dense {cb['peak_kv_bytes']} bytes")
    print(f"serve_paged_makespan_parity,"
          f"{cb['makespan'] / pg['makespan']:.2f}x,"
          f"token_parity={parity}")

    # --- shared-prefix trace: radix-trie KV reuse + chunked prefill ---
    # every prompt opens with the same 16 tokens, block size 4, chunk
    # budget 8 tokens/tick (DESIGN.md §12).  The cached run must be
    # token-identical to the uncached paged run and skip a substantial
    # fraction of prefill work.
    sp_rng = np.random.default_rng(1)
    sp_trace = make_trace(n_requests, sp_rng)
    shared = sp_rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    sp_prompts = [
        np.concatenate([shared, sp_rng.integers(
            0, cfg.vocab_size, (r["prompt_len"],)).astype(np.int32)])
        for r in sp_trace]
    sp_max_len = 16 + 24 + 16 + 8  # prefix + prompt + gen + headroom
    sp_base = run_continuous(cfg, params, sp_trace, sp_prompts, slots,
                             sp_max_len, kv_layout="paged", kv_block_size=4)
    sp = run_continuous(cfg, params, sp_trace, sp_prompts, slots, sp_max_len,
                        kv_layout="paged", kv_block_size=4,
                        prefix_cache=True, prefill_chunk_tokens=8)
    sp_parity = all(sp["outputs"][u] == sp_base["outputs"][u]
                    for u in sp_base["outputs"])
    assert sp_parity, "prefix-cached greedy output diverged from uncached paged"
    total_prompt_tokens = sum(len(p) for p in sp_prompts)
    saved = sp["prefix"]["tokens_saved"]
    frac = saved / total_prompt_tokens
    print(f"serve_prefix_tokens_saved,{saved},"
          f"fraction={frac:.2f} hits={sp['prefix']['hits']} "
          f"of {total_prompt_tokens} prompt tokens (shared-prefix trace, "
          f"block=4 chunk=8) token_parity={sp_parity}")
    assert frac >= 0.30, (
        f"prefix cache saved only {frac:.0%} of prefill tokens (need >=30%)")

    # --- kv_dtype sweep: quantized page pools (DESIGN.md §13) ---
    # the same trace served at each KV storage layout; fp32 reuses the
    # paged run above.  The record keeps bytes/token (scale pages
    # included) and the peak pool footprint — CI asserts the int8 row
    # compresses to <= 0.55x fp32 from this JSON.
    kv_sweep = {}
    for kvd in kv_dtypes:
        r = pg if kvd == "fp32" else run_continuous(
            cfg, params, trace, prompts, slots, max_len,
            kv_layout="paged", kv_block_size=kv_block_size, kv_dtype=kvd)
        kv_sweep[kvd] = {
            "kv_bytes_per_token": r["kv_bytes_per_token"],
            "peak_kv_bytes": r["peak_kv_bytes"],
            "peak_used_blocks": r["peak_used_blocks"],
            "gather_bytes_per_token": r["gather_bytes_per_token"],
            "makespan": r["makespan"],
        }
        print(f"serve_paged_kv_bytes_per_token[{kvd}],"
              f"{r['kv_bytes_per_token']:.0f},"
              f"peak_kv_bytes={r['peak_kv_bytes']} "
              f"peak_blocks={r['peak_used_blocks']}")
    if "fp32" in kv_sweep and "int8" in kv_sweep:
        ratio = (kv_sweep["int8"]["kv_bytes_per_token"]
                 / kv_sweep["fp32"]["kv_bytes_per_token"])
        print(f"serve_paged_kv_compression_int8,{ratio:.3f}x,"
              f"bytes_per_token_vs_fp32 (target <=0.55)")

    # disjoint trace: the cache must not cost anything when nothing is
    # shared — same arrivals as the paged baseline, prefix cache on
    dp = run_continuous(cfg, params, trace, prompts, slots, max_len,
                        kv_layout="paged", kv_block_size=kv_block_size,
                        prefix_cache=True)
    dp_parity = all(dp["outputs"][u] == pg["outputs"][u]
                    for u in pg["outputs"])
    assert dp_parity, "prefix-cache engine diverged on the disjoint trace"
    assert dp["makespan"] <= pg["makespan"], (
        f"prefix cache regressed disjoint-trace makespan: "
        f"{dp['makespan']} > {pg['makespan']}")
    print(f"serve_prefix_disjoint_makespan_parity,"
          f"{pg['makespan'] / dp['makespan']:.2f}x,"
          f"token_parity={dp_parity} (no regression when nothing shares)")

    if json_path:
        record = {
            "bench": "serve_throughput",
            "requests": n_requests,
            "slots": slots,
            "max_len": max_len,
            "lockstep": lk,
            "continuous": cb,
            "paged": pg,
            "paged_token_parity": parity,
            "kv_dtype_sweep": kv_sweep,
            "prefix": {
                "tokens_saved": saved,
                "hits": sp["prefix"]["hits"],
                "evicted": sp["prefix"]["evicted"],
                "saved_fraction": frac,
                "total_prompt_tokens": total_prompt_tokens,
                "shared_trace_token_parity": sp_parity,
                "shared_trace_makespan": sp["makespan"],
                "shared_trace_makespan_uncached": sp_base["makespan"],
                "disjoint_token_parity": dp_parity,
                "disjoint_makespan": dp["makespan"],
                "disjoint_makespan_uncached": pg["makespan"],
            },
        }
        for eng_rec in (cb, pg, sp, sp_base, dp):
            eng_rec.pop("outputs", None)  # token lists stay out of the record
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2, default=float)
        print(f"wrote {json_path}")
    return True


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--kv-block-size", type=int, default=16)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full record (incl. per-tick block-pool "
                    "occupancy) as JSON")
    ap.add_argument("--kv-dtype", default="all",
                    choices=("fp32", "int8", "fp8_e4m3", "all"),
                    help="KV storage layout(s) for the paged kv_dtype "
                    "sweep (default: all three)")
    args = ap.parse_args()
    dtypes = (("fp32", "int8", "fp8_e4m3") if args.kv_dtype == "all"
              else ("fp32", args.kv_dtype)
              if args.kv_dtype != "fp32" else ("fp32",))
    main(args.requests, args.slots, args.kv_block_size, args.json,
         kv_dtypes=dtypes)
