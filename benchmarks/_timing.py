"""Shared benchmark timing helpers (DESIGN.md §10).

Every benchmark used to hand-roll the same loop — warmup call,
``jax.block_until_ready``, ``perf_counter`` delta — with small accidental
differences (warmup or not, blocking or not).  One definition here means
every benchmark times device work the same way:

* :func:`time_device_fn` — the kernel-bench loop: ``warmup`` blocked
  calls (compilation + first-touch excluded), then ``iters`` blocked
  calls under one timer.  Returns mean seconds per call.
* :class:`Stopwatch` — a ``with``-block wall timer for end-to-end
  sections (a whole serve run), where the work inside blocks on its own
  host syncs and a warmup pass would change the measurement.

jax is imported lazily so importing this module (or anything that
re-exports it) never pays jax start-up cost.
"""

from __future__ import annotations

import time
from typing import Any, Callable


def time_device_fn(
    fn: Callable[[], Any], iters: int = 3, warmup: int = 1
) -> float:
    """Mean seconds per call of ``fn``, blocking on device results.

    ``fn`` returns a jax array (or pytree); every call is wrapped in
    ``jax.block_until_ready`` so async dispatch cannot hide device time.
    ``warmup`` calls run (and block) outside the timed region, absorbing
    compilation.
    """
    import jax

    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def time_device_fn_us(
    fn: Callable[[], Any], iters: int = 3, warmup: int = 1
) -> float:
    """:func:`time_device_fn` in microseconds (the kernel-bench unit)."""
    return time_device_fn(fn, iters=iters, warmup=warmup) * 1e6


class Stopwatch:
    """Wall-clock timer: ``with Stopwatch() as sw: ...; sw.seconds``."""

    def __init__(self) -> None:
        self.seconds = 0.0

    def __enter__(self) -> "Stopwatch":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        self.seconds = time.perf_counter() - self._t0
        return False
