"""Paper §I claim: softmax execution share grows with sequence length and
exceeds the matmul share (59.2% of BERT-base time at seq 512 on GPU).

We time exact softmax vs the attention matmuls on this host (CPU XLA — the
absolute share differs from a GPU, the *trend* is the claim), and report the
STAR engine's op-count view: with the counter+VMM trick a softmax row costs
d CAM searches + 1 VMM + 1 divide instead of d exps + a d-sum.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._timing import time_device_fn
from repro.hwmodel import constants as C
from repro.hwmodel.star_engine import system_efficiency


def _time(f, *args, iters=5):
    return time_device_fn(lambda: f(*args), iters=iters)


def run(seqs=(128, 256, 512)) -> list:
    d, h = C.BERT_D_MODEL, C.BERT_HEADS
    rows = []
    rng = np.random.default_rng(0)
    for s in seqs:
        q = jnp.asarray(rng.normal(size=(1, h, s, d // h)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, h, s, d // h)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, h, s, d // h)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(1, s, d)), jnp.float32)
        wq = jnp.asarray(rng.normal(size=(d, d)) * 0.02, jnp.float32)

        mm = jax.jit(lambda q, k, v, x, wq: (
            jnp.einsum("bhqd,bhkd->bhqk", q, k),
            x @ wq, x @ wq, x @ wq, x @ wq,  # QKVO projections
        ))
        sm = jax.jit(lambda scores: jax.nn.softmax(scores, axis=-1))
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k)
        pv = jax.jit(lambda p, v: jnp.einsum("bhqk,bhkd->bhqd", p, v))

        t_mm = _time(mm, q, k, v, x, wq) + _time(pv, jax.nn.softmax(scores), v)
        t_sm = _time(sm, scores)
        frac = t_sm / (t_sm + t_mm)
        # the hwmodel's accelerator-side share (operand-granularity engine)
        hw = system_efficiency(s, softmax_on_rram=False, vector_pipeline=False)
        rows.append({
            "seq": s,
            "host_softmax_ms": t_sm * 1e3,
            "host_matmul_ms": t_mm * 1e3,
            "host_softmax_share": frac,
            "accel_model_softmax_share": hw["softmax_share"],
        })
    return rows


def main():
    rows = run()
    shares = [r["host_softmax_share"] for r in rows]
    model_shares = [r["accel_model_softmax_share"] for r in rows]
    for r in rows:
        print(f"softmax_fraction_seq{r['seq']},{r['host_softmax_ms']*1e3:.1f},"
              f"host_share={r['host_softmax_share']:.3f},"
              f"accel_model_share={r['accel_model_softmax_share']:.3f}")
    assert shares[-1] > shares[0], "softmax share must grow with seq length"
    assert model_shares[-1] > model_shares[0]
    return rows


if __name__ == "__main__":
    main()
