"""Paper Fig 3: computing efficiency (GOPS/s/W) — STAR vs GPU / PipeLayer /
ReTransformer, from the component hardware model."""

from repro.hwmodel.star_engine import fig3, system_efficiency


def main():
    f = fig3()
    print(f"fig3_star_gops_w,{f['star_model']:.1f},paper=612.66")
    print(f"fig3_retransformer_gops_w,{f['retransformer_model']:.1f},paper=467.7")
    print(f"fig3_star_vs_retransformer,{f['star_vs_retransformer_model']:.3f},paper=1.31")
    print(f"fig3_star_vs_gpu,{f['star_model']/f['gpu_paper']:.1f},paper=30.63")
    print(f"fig3_star_vs_pipelayer,{f['star_model']/f['pipelayer_paper']:.2f},paper=4.32")
    # ablation: pipeline alone / rram-softmax alone
    base = system_efficiency(128, softmax_on_rram=False, vector_pipeline=False)
    sm_only = system_efficiency(128, softmax_on_rram=True, vector_pipeline=False)
    pipe_only = system_efficiency(128, softmax_on_rram=False, vector_pipeline=True)
    print(f"fig3_ablation_base,{base['gops_per_w']:.1f},")
    print(f"fig3_ablation_rram_softmax_only,{sm_only['gops_per_w']:.1f},")
    print(f"fig3_ablation_pipeline_only,{pipe_only['gops_per_w']:.1f},")
    assert abs(f["star_model"] - 612.66) / 612.66 < 0.25
    assert abs(f["retransformer_model"] - 467.7) / 467.7 < 0.25
    assert 1.0 < f["star_vs_retransformer_model"] < 1.7
    return f


if __name__ == "__main__":
    main()
