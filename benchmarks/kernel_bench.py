"""Kernel micro-bench, driven by the ``repro.ops`` registry.

Instead of hardcoded function calls, the sweep *iterates the registered
backends* for each op — a new backend shows up in the sweep the moment it
is registered — and every record carries the resolved spec, so an emitted
JSON row is a reproducible invocation, not just a number.

Wall-times on CPU are interpret-mode numbers (NOT TPU performance); the
derived column reports the kernel's arithmetic-intensity bookkeeping used
by §Perf.

    PYTHONPATH=src python -m benchmarks.kernel_bench                # all impls
    PYTHONPATH=src python -m benchmarks.kernel_bench --impl pallas  # one impl
    PYTHONPATH=src python -m benchmarks.kernel_bench --json out.json
"""

from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from benchmarks._timing import time_device_fn_us as _t
from repro import ops
from repro.core.fixedpoint import DEFAULT_FORMAT


def _record(records, name, us, spec, **derived):
    row = {"name": name, "us": round(us, 1), "spec": ops.spec_json(spec), **derived}
    records.append(row)
    extra = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.0f},{extra}" if extra else f"{name},{us:.0f}")


def _valid_spec(spec):
    """True when the selected backend's capability table accepts the spec."""
    try:
        ops.resolve(spec)
        return True
    except ops.OpDispatchError:
        return False


def sweep_softmax(records: List[Dict[str, Any]], impl_filter: Optional[str]) -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 512)) * 4, jnp.float32)
    # STAR op accounting: per element 1 quant + 1 LUT; per row 1 VMM(256) + 1 div
    star_ops = x.size * 2 + x.shape[0] * (DEFAULT_FORMAT.num_levels * 2 + 1)
    for backend in ops.backends("softmax"):
        if impl_filter and backend.impl != impl_filter:
            continue
        kind = "exact" if backend.capabilities.get("kind") == ("exact",) else "star"
        spec = ops.validate(ops.SoftmaxSpec(impl=backend.impl, kind=kind))
        us = _t(lambda: ops.softmax(x, spec))
        derived = {"engine_ops": star_ops} if kind == "star" else {}
        _record(records, f"softmax_{backend.impl}_64x512", us, spec, **derived)


def sweep_attention(records: List[Dict[str, Any]], impl_filter: Optional[str]) -> None:
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    flops = 4 * 256 * 256 * 4 * 64  # QK^T + PV
    for backend in ops.backends("attention"):
        if impl_filter and backend.impl != impl_filter:
            continue
        spec = ops.validate(ops.AttentionSpec(
            impl=backend.impl, causal=True, block_q=64, block_k=64, block_kv=64
        ))
        us = _t(lambda: ops.attention(q, k, v, spec), iters=2)
        _record(records, f"attn_{backend.impl}_256", us, spec, attn_flops=flops)
        if _valid_spec(spec := ops.AttentionSpec(
            impl=backend.impl, causal=True, block_q=64, block_k=64, pv_int8=True
        )):
            us8 = _t(lambda: ops.attention(q, k, v, spec), iters=2)
            _record(
                records, f"attn_{backend.impl}_256_int8pv", us8, spec,
                pv_bytes_saved="0.5x",
            )


def sweep_paged_decode(
    records: List[Dict[str, Any]], impl_filter: Optional[str]
) -> None:
    """Paged decode: pool size x active length, every registered backend.

    Wall-time on CPU is interpret-mode noise; the column that matters is
    ``gather_bytes`` — the counted K+V bytes the backend reads from the
    page pool per decode step (``ops.paged_gather_bytes``).  The gather
    adapters pay the full ``S*W*bs`` table window regardless of occupancy;
    ``pallas_paged`` pays only live pages, so its advantage grows with
    pool/active ratio (the ``bytes_vs_gather`` column and the summary
    speedup rows).
    """
    rng = np.random.default_rng(0)
    s, bs, hq, hkv, d = 4, 16, 4, 2, 64
    ratios: Dict[tuple, Dict[str, int]] = {}
    for w in (4, 16):  # table width -> per-slot pool of w*bs rows
        n = s * w + 1  # + scratch block 0
        q = jnp.asarray(rng.normal(size=(s, 1, hq, d)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(n, bs, hkv, d)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(n, bs, hkv, d)), jnp.float32)
        tables = jnp.arange(1, s * w + 1, dtype=jnp.int32).reshape(s, w)
        for live in (8, w * bs // 2, w * bs):
            kvl = jnp.full((s,), live, jnp.int32)
            for backend in ops.backends("paged_attention"):
                if impl_filter and backend.impl != impl_filter:
                    continue
                spec = ops.validate(
                    ops.PagedAttentionSpec(impl=backend.impl, block_size=bs)
                )
                us = _t(
                    lambda: ops.paged_attention(
                        q, kp, vp, tables, spec,
                        kv_valid_len=kvl, kv_len=w * bs,
                    ),
                    iters=2,
                )
                gb = ops.paged_gather_bytes(
                    backend.impl, table_width=w, block_size=bs,
                    live_lens=[live] * s, num_kv_heads=hkv, head_dim=d,
                )
                ratios.setdefault((w, live), {})[backend.impl] = gb
                _record(
                    records,
                    f"paged_decode_{backend.impl}_pool{w * bs}_live{live}",
                    us, spec, gather_bytes=gb,
                    pool_rows=w * bs, live_rows=live,
                )
    # interpret-normalized speedup: counted pool-read bytes, gather vs
    # gather-free, per (pool, active) point
    for (w, live), by_impl in sorted(ratios.items()):
        if "xla" in by_impl and "pallas_paged" in by_impl:
            ratio = by_impl["xla"] / by_impl["pallas_paged"]
            row = {
                "name": f"paged_decode_bytes_speedup_pool{w * bs}_live{live}",
                "speedup": round(ratio, 2),
                "gather_bytes": by_impl["xla"],
                "pallas_paged_bytes": by_impl["pallas_paged"],
            }
            records.append(row)
            print(f"{row['name']},{ratio:.2f}x,counted_pool_read_bytes")


def sweep_paged_kv_dtype(
    records: List[Dict[str, Any]], impl_filter: Optional[str],
    dtype_filter: Optional[str] = None,
) -> None:
    """Quantized paged decode across ``kv_dtype`` layouts (DESIGN.md §13).

    Fixed at the pool-256 / live-8 acceptance point of the paged sweep:
    the column that matters is ``kv_bytes_per_token`` — counted pool-read
    bytes per decode token (codes + the per-(block, head) scale rows) —
    plus ``pool_bytes``, the whole pool's resident footprint at that
    dtype.  Two invariants are asserted, not just printed: the int8
    layout reads ≤ 0.55x the fp32 bytes/token (the compression target CI
    re-checks from serve_throughput), and the quantized pallas_paged
    jaxpr still never materializes the [S, W*bs, H, D] gathered window.
    """
    from repro.core import kvquant

    rng = np.random.default_rng(0)
    s, w, bs, hq, hkv, d, live = 4, 16, 16, 4, 2, 64, 8
    n = s * w + 1
    q = jnp.asarray(rng.normal(size=(s, 1, hq, d)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(n, bs, hkv, d)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(n, bs, hkv, d)), jnp.float32)
    tables = jnp.arange(1, s * w + 1, dtype=jnp.int32).reshape(s, w)
    kvl = jnp.full((s,), live, jnp.int32)
    per_tok: Dict[tuple, float] = {}
    for kv_dtype in kvquant.KV_DTYPES:
        if dtype_filter and kv_dtype != dtype_filter:
            continue
        if kv_dtype == "fp32":
            kp, vp, scales = kf, vf, None
        else:
            kp, ks = kvquant.quantize_blocks(kf, kv_dtype)
            vp, vs = kvquant.quantize_blocks(vf, kv_dtype)
            scales = (ks, vs)
        scale_bytes = 2 * 4 * hkv if scales is not None else 0  # k+v, f32
        for backend in ops.backends("paged_attention"):
            if impl_filter and backend.impl != impl_filter:
                continue
            spec = ops.validate(ops.PagedAttentionSpec(
                impl=backend.impl, block_size=bs, kv_dtype=kv_dtype))

            def call():
                return ops.paged_attention(
                    q, kp, vp, tables, spec,
                    kv_valid_len=kvl, kv_len=w * bs, kv_scales=scales,
                )

            us = _t(call, iters=2)
            gb = ops.paged_gather_bytes(
                backend.impl, table_width=w, block_size=bs,
                live_lens=[live] * s, num_kv_heads=hkv, head_dim=d,
                dtype_bytes=kp.dtype.itemsize,
                scale_bytes_per_block=scale_bytes,
            )
            bpt = gb / s
            pool_bytes = n * (2 * bs * hkv * d * kp.dtype.itemsize
                              + scale_bytes)
            per_tok[(backend.impl, kv_dtype)] = bpt
            _record(
                records,
                f"paged_decode_{backend.impl}_{kv_dtype}_pool{w * bs}"
                f"_live{live}",
                us, spec, gather_bytes=gb, kv_bytes_per_token=round(bpt, 1),
                pool_bytes=pool_bytes,
            )
            if backend.impl == "pallas_paged" and scales is not None:
                assert not _materializes_window(
                    call, (s, w * bs, hkv, d)
                ), f"{kv_dtype} pallas_paged materialized the gathered window"
    for impl in sorted({i for i, _ in per_tok}):
        f32, i8 = per_tok.get((impl, "fp32")), per_tok.get((impl, "int8"))
        if f32 is None or i8 is None:
            continue
        ratio = i8 / f32
        row = {
            "name": f"paged_decode_kv_compression_{impl}_pool256_live8",
            "int8_vs_fp32_bytes_per_token": round(ratio, 3),
            "fp32_bytes_per_token": round(f32, 1),
            "int8_bytes_per_token": round(i8, 1),
        }
        records.append(row)
        print(f"{row['name']},{ratio:.3f}x,counted_bytes_per_token")
        assert ratio <= 0.55, (
            f"int8 paged reads {ratio:.2f}x the fp32 bytes/token for "
            f"{impl} (compression target: <= 0.55x)"
        )


def _materializes_window(call, shape) -> bool:
    """True if any intermediate in ``call``'s jaxpr has ``shape`` — the
    gathered-operand probe from tests/test_paged_kernel.py, applied to the
    quantized kernel here so the bench's perf claim carries its own
    structural check."""
    import jax

    def walk(jaxpr, acc):
        for eqn in jaxpr.eqns:
            acc.extend(v.aval for v in eqn.outvars)
            for val in eqn.params.values():
                items = val if isinstance(val, (tuple, list)) else [val]
                for item in items:
                    if isinstance(item, jax.core.ClosedJaxpr):
                        walk(item.jaxpr, acc)
                    elif isinstance(item, jax.core.Jaxpr):
                        walk(item, acc)
        return acc

    avals = walk(jax.make_jaxpr(call)().jaxpr, [])
    return any(getattr(a, "shape", None) == tuple(shape) for a in avals)


def sweep_matmul(records: List[Dict[str, Any]], impl_filter: Optional[str]) -> None:
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 256)) * 0.05, jnp.float32)
    for backend in ops.backends("matmul"):
        if impl_filter and backend.impl != impl_filter:
            continue
        spec = ops.validate(ops.MatmulSpec(impl=backend.impl))
        us = _t(lambda: ops.matmul(a, w, spec))
        derived = {}
        if backend.impl == "hwmodel":  # crossbar accounting only where one exists
            xbar = spec.crossbar
            derived["xbar_reads"] = (256 // xbar.tile_rows) * (256 // xbar.tile_cols)
        _record(records, f"matmul_{backend.impl}_64x256x256", us, spec, **derived)


def sweep_ssd_scan(records: List[Dict[str, Any]], impl_filter: Optional[str]) -> None:
    rng = np.random.default_rng(0)
    xdt = jnp.asarray(rng.normal(size=(1, 256, 8, 32)), jnp.float32)
    ad = -jnp.abs(jnp.asarray(rng.normal(size=(1, 256, 8)) * 0.1, jnp.float32))
    bm = jnp.asarray(rng.normal(size=(1, 256, 32)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(1, 256, 32)) * 0.3, jnp.float32)
    for backend in ops.backends("ssd_scan"):
        if impl_filter and backend.impl != impl_filter:
            continue
        spec = ops.validate(ops.ScanSpec(impl=backend.impl, chunk=64))
        us = _t(lambda: ops.ssd_scan(xdt, ad, bm, cm, spec)[0], iters=2)
        _record(
            records, f"ssd_scan_{backend.impl}_256", us, spec,
            vmem_state_bytes=8 * 32 * 32 * 4,
        )


def main(argv: Optional[List[str]] = None) -> bool:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--impl", default=None,
        help="only sweep this registry impl (default: every registered backend)",
    )
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write the records (incl. resolved specs) as JSON",
    )
    ap.add_argument(
        "--only", default=None,
        choices=("softmax", "attention", "paged_decode", "paged_kv_dtype",
                 "ssd_scan", "matmul"),
        help="run a single sweep (e.g. --only paged_decode for the "
        "BENCH_paged_decode.json emission, --only paged_kv_dtype for "
        "BENCH_kv_quant.json)",
    )
    ap.add_argument(
        "--kv-dtype", default=None, choices=("fp32", "int8", "fp8_e4m3"),
        help="restrict the paged_kv_dtype sweep to one KV storage layout "
        "(default: sweep all three)",
    )
    args = ap.parse_args(argv)

    sweeps = {
        "softmax": sweep_softmax,
        "attention": sweep_attention,
        "paged_decode": sweep_paged_decode,
        "paged_kv_dtype": lambda r, i: sweep_paged_kv_dtype(
            r, i, args.kv_dtype),
        "ssd_scan": sweep_ssd_scan,
        "matmul": sweep_matmul,
    }
    records: List[Dict[str, Any]] = []
    for name, fn in sweeps.items():
        if args.only is None or args.only == name:
            fn(records, args.impl)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=2)
        print(f"# wrote {len(records)} records to {args.json}")
    return True


if __name__ == "__main__":
    main()
