"""Kernel micro-bench, driven by the ``repro.ops`` registry.

Instead of hardcoded function calls, the sweep *iterates the registered
backends* for each op — a new backend shows up in the sweep the moment it
is registered — and every record carries the resolved spec, so an emitted
JSON row is a reproducible invocation, not just a number.

Wall-times on CPU are interpret-mode numbers (NOT TPU performance); the
derived column reports the kernel's arithmetic-intensity bookkeeping used
by §Perf.

    PYTHONPATH=src python -m benchmarks.kernel_bench                # all impls
    PYTHONPATH=src python -m benchmarks.kernel_bench --impl pallas  # one impl
    PYTHONPATH=src python -m benchmarks.kernel_bench --json out.json
"""

from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from benchmarks._timing import time_device_fn_us as _t
from repro import ops
from repro.core.fixedpoint import DEFAULT_FORMAT


def _record(records, name, us, spec, **derived):
    row = {"name": name, "us": round(us, 1), "spec": ops.spec_json(spec), **derived}
    records.append(row)
    extra = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.0f},{extra}" if extra else f"{name},{us:.0f}")


def _valid_spec(spec):
    """True when the selected backend's capability table accepts the spec."""
    try:
        ops.resolve(spec)
        return True
    except ops.OpDispatchError:
        return False


def sweep_softmax(records: List[Dict[str, Any]], impl_filter: Optional[str]) -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 512)) * 4, jnp.float32)
    # STAR op accounting: per element 1 quant + 1 LUT; per row 1 VMM(256) + 1 div
    star_ops = x.size * 2 + x.shape[0] * (DEFAULT_FORMAT.num_levels * 2 + 1)
    for backend in ops.backends("softmax"):
        if impl_filter and backend.impl != impl_filter:
            continue
        kind = "exact" if backend.capabilities.get("kind") == ("exact",) else "star"
        spec = ops.validate(ops.SoftmaxSpec(impl=backend.impl, kind=kind))
        us = _t(lambda: ops.softmax(x, spec))
        derived = {"engine_ops": star_ops} if kind == "star" else {}
        _record(records, f"softmax_{backend.impl}_64x512", us, spec, **derived)


def sweep_attention(records: List[Dict[str, Any]], impl_filter: Optional[str]) -> None:
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    flops = 4 * 256 * 256 * 4 * 64  # QK^T + PV
    for backend in ops.backends("attention"):
        if impl_filter and backend.impl != impl_filter:
            continue
        spec = ops.validate(ops.AttentionSpec(
            impl=backend.impl, causal=True, block_q=64, block_k=64, block_kv=64
        ))
        us = _t(lambda: ops.attention(q, k, v, spec), iters=2)
        _record(records, f"attn_{backend.impl}_256", us, spec, attn_flops=flops)
        if _valid_spec(spec := ops.AttentionSpec(
            impl=backend.impl, causal=True, block_q=64, block_k=64, pv_int8=True
        )):
            us8 = _t(lambda: ops.attention(q, k, v, spec), iters=2)
            _record(
                records, f"attn_{backend.impl}_256_int8pv", us8, spec,
                pv_bytes_saved="0.5x",
            )


def sweep_matmul(records: List[Dict[str, Any]], impl_filter: Optional[str]) -> None:
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 256)) * 0.05, jnp.float32)
    for backend in ops.backends("matmul"):
        if impl_filter and backend.impl != impl_filter:
            continue
        spec = ops.validate(ops.MatmulSpec(impl=backend.impl))
        us = _t(lambda: ops.matmul(a, w, spec))
        derived = {}
        if backend.impl == "hwmodel":  # crossbar accounting only where one exists
            xbar = spec.crossbar
            derived["xbar_reads"] = (256 // xbar.tile_rows) * (256 // xbar.tile_cols)
        _record(records, f"matmul_{backend.impl}_64x256x256", us, spec, **derived)


def sweep_ssd_scan(records: List[Dict[str, Any]], impl_filter: Optional[str]) -> None:
    rng = np.random.default_rng(0)
    xdt = jnp.asarray(rng.normal(size=(1, 256, 8, 32)), jnp.float32)
    ad = -jnp.abs(jnp.asarray(rng.normal(size=(1, 256, 8)) * 0.1, jnp.float32))
    bm = jnp.asarray(rng.normal(size=(1, 256, 32)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(1, 256, 32)) * 0.3, jnp.float32)
    for backend in ops.backends("ssd_scan"):
        if impl_filter and backend.impl != impl_filter:
            continue
        spec = ops.validate(ops.ScanSpec(impl=backend.impl, chunk=64))
        us = _t(lambda: ops.ssd_scan(xdt, ad, bm, cm, spec)[0], iters=2)
        _record(
            records, f"ssd_scan_{backend.impl}_256", us, spec,
            vmem_state_bytes=8 * 32 * 32 * 4,
        )


def main(argv: Optional[List[str]] = None) -> bool:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--impl", default=None,
        help="only sweep this registry impl (default: every registered backend)",
    )
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write the records (incl. resolved specs) as JSON",
    )
    args = ap.parse_args(argv)

    records: List[Dict[str, Any]] = []
    sweep_softmax(records, args.impl)
    sweep_attention(records, args.impl)
    sweep_ssd_scan(records, args.impl)
    sweep_matmul(records, args.impl)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=2)
        print(f"# wrote {len(records)} records to {args.json}")
    return True


if __name__ == "__main__":
    main()
