"""Kernel micro-bench: interpret-mode timings (CPU correctness harness) +
the roofline-relevant op accounting for the STAR kernels.

Wall-times here are CPU-interpret numbers (NOT TPU performance); the derived
column reports the kernel's arithmetic-intensity bookkeeping used by §Perf.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import DEFAULT_FORMAT
from repro.kernels.flash_star.ops import flash_star_op
from repro.kernels.star_softmax.ops import star_softmax_op
from repro.kernels.crossbar_matmul.ops import crossbar_matmul_op


def _t(f, iters=3):
    jax.block_until_ready(f())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f())
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 512)) * 4, jnp.float32)
    us = _t(lambda: star_softmax_op(x, DEFAULT_FORMAT))
    # STAR op accounting: per element 1 quant + 1 LUT; per row 1 VMM(256) + 1 div
    ops = x.size * 2 + x.shape[0] * (DEFAULT_FORMAT.num_levels * 2 + 1)
    print(f"star_softmax_64x512,{us:.0f},engine_ops={ops}")

    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.float32)
    us = _t(lambda: flash_star_op(q, k, v, causal=True, block_q=64, block_k=64), iters=2)
    flops = 4 * 256 * 256 * 4 * 64  # QK^T + PV
    print(f"flash_star_256,{us:.0f},attn_flops={flops}")
    us8 = _t(lambda: flash_star_op(q, k, v, causal=True, pv_int8=True,
                                   block_q=64, block_k=64), iters=2)
    print(f"flash_star_256_int8pv,{us8:.0f},pv_bytes_saved=0.5x")

    from repro.kernels.ssd_scan.ops import ssd_scan_op
    xdt = jnp.asarray(rng.normal(size=(1, 256, 8, 32)), jnp.float32)
    ad = -jnp.abs(jnp.asarray(rng.normal(size=(1, 256, 8)) * 0.1, jnp.float32))
    bm = jnp.asarray(rng.normal(size=(1, 256, 32)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(1, 256, 32)) * 0.3, jnp.float32)
    us = _t(lambda: ssd_scan_op(xdt, ad, bm, cm, chunk=64)[0], iters=2)
    print(f"ssd_scan_256,{us:.0f},vmem_state_bytes={8*32*32*4}")

    a = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 256)) * 0.05, jnp.float32)
    us = _t(lambda: crossbar_matmul_op(a, w))
    print(f"crossbar_matmul_64x256x256,{us:.0f},xbar_reads={(256//128)*(256//128)}")
    return True


if __name__ == "__main__":
    main()
