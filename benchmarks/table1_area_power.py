"""Paper Table I: softmax engine area/power vs CMOS baseline and Softermax."""

from repro.hwmodel.star_engine import table1


def main():
    t = table1()
    print(f"table1_area_ours,{t['ours_model']['area']:.4f},paper=0.06")
    print(f"table1_power_ours,{t['ours_model']['power']:.4f},paper=0.05")
    print(f"table1_area_vs_softermax,{t['vs_softermax_model']['area']:.4f},paper=0.20")
    print(f"table1_power_vs_softermax,{t['vs_softermax_model']['power']:.4f},paper=0.44")
    print(f"table1_abs_area_mm2,{t['ours_abs']['area_mm2']:.5f},")
    print(f"table1_abs_power_w,{t['ours_abs']['power_w']:.5f},")
    # bands: same order of magnitude + strictly better than Softermax
    assert 0.02 < t["ours_model"]["area"] < 0.12
    assert 0.02 < t["ours_model"]["power"] < 0.12
    assert t["ours_model"]["area"] < t["softermax"]["area"]
    assert t["ours_model"]["power"] < t["softermax"]["power"]
    return t


if __name__ == "__main__":
    main()
