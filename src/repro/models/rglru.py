"""RecurrentGemma (Griffin) hybrid: RG-LRU recurrent blocks + local attention.

Pattern (cfg.block_pattern): ("recurrent", "recurrent", "attention") repeated;
26 layers = 8 scanned periods of 3 + a 2-layer recurrent tail (DESIGN.md §5).
The local-attention layers run through the STAR softmax engine; RG-LRU layers
have no softmax (noted inapplicability).

RG-LRU recurrence: h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*(i_t*x_t), with
a_t = exp(c * r_t * -softplus(lam)), gates r, i = sigmoid(linear(x)).
Train/prefill uses an associative scan (log-depth), decode a single step.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import with_logical_constraint as wlc
from repro.models import layers as L
from repro.models.param import ParamSpec
from repro.models.transformer import _stack_specs, cross_entropy

Params = Dict[str, Any]
_LRU_C = 8.0


def spec_rglru_block(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    pd = L.pdtype(cfg)
    return {
        "ln": L.spec_rmsnorm(cfg),
        "wx": ParamSpec((d, w), ("embed", "mlp"), pd, "fan_in"),
        "wgate": ParamSpec((d, w), ("embed", "mlp"), pd, "fan_in"),
        "conv": L.spec_conv1d(cfg, w, cfg.conv_width),
        "wa": ParamSpec((w, w), ("embed", "mlp"), pd, "fan_in"),
        "wi": ParamSpec((w, w), ("embed", "mlp"), pd, "fan_in"),
        "lam": ParamSpec((w,), ("mlp",), pd, "ones"),
        "wout": ParamSpec((w, d), ("mlp", "embed"), pd, "fan_in"),
        "ln_mlp": L.spec_rmsnorm(cfg),
        "mlp": L.spec_mlp(cfg),
    }


def spec_attn_block(cfg: ModelConfig) -> Params:
    return {
        "ln": L.spec_rmsnorm(cfg),
        "attn": L.spec_attention(cfg),
        "ln_mlp": L.spec_rmsnorm(cfg),
        "mlp": L.spec_mlp(cfg),
    }


def rglru_scan(
    x: jax.Array,  # [B, T, W] gated input (i_t * x_t already applied)
    a: jax.Array,  # [B, T, W] decay in (0, 1)
    h0: Optional[jax.Array],  # [B, W]
) -> Tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.
    Returns (h_all [B,T,W], h_last [B,W])."""
    b_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x
    if h0 is not None:
        # fold h0 into the first step
        b_in = b_in.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b1 * a2 + b2

    a_s, b_s = jax.lax.associative_scan(combine, (a, b_in), axis=1)
    return b_s, b_s[:, -1]


def recurrent_block(
    p: Params,
    h: jax.Array,
    cfg: ModelConfig,
    cache: Optional[Params] = None,  # {"conv": [B,W-1,w], "h": [B,w]}
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    dt = L.cdtype(cfg)
    x_in = L.rmsnorm(p["ln"], h, cfg.norm_eps)
    xb = jnp.einsum("btd,dw->btw", x_in, p["wx"].astype(dt))
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x_in, p["wgate"].astype(dt)))

    conv_out, new_conv = L.causal_conv1d(
        p["conv"], xb, None if cache is None else cache["conv"]
    )
    if cache is None and return_state:
        xp = jnp.pad(xb, ((0, 0), (cfg.conv_width - 1, 0), (0, 0)))
        new_conv = xp[:, -(cfg.conv_width - 1):, :]

    xf = conv_out.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xf, p["wa"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", xf, p["wi"].astype(jnp.float32)))
    log_a = -_LRU_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = i * xf

    h0 = None if cache is None else cache["h"].astype(jnp.float32)
    hs, h_last = rglru_scan(gated, a, h0)
    y = (hs.astype(dt) * gate)
    y = wlc(y, ("batch", "seq", "mlp"))
    out = jnp.einsum("btw,wd->btd", y, p["wout"].astype(dt))
    out = wlc(out, ("batch", "seq", "embed"))
    new_cache = None
    if cache is not None or return_state:
        new_cache = {"conv": new_conv, "h": h_last.astype(jnp.float32)}
    res = h + out
    hn = L.rmsnorm(p["ln_mlp"], res, cfg.norm_eps)
    return res + L.mlp(p["mlp"], hn, cfg), new_cache


def local_attn_block(
    p: Params,
    h: jax.Array,
    cfg: ModelConfig,
    cache: Optional[Params] = None,
    cache_len: Optional[jax.Array] = None,
    return_kv: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    a, new_cache, kv = L.attention_block(
        p["attn"], L.rmsnorm(p["ln"], h, cfg.norm_eps), cfg,
        causal=True, sliding_window=cfg.local_window,
        cache=None if cache is None else {**cache, "len": cache_len},
    )
    res = h + L.attention_out(p["attn"], a, cfg)
    hn = L.rmsnorm(p["ln_mlp"], res, cfg.norm_eps)
    out = res + L.mlp(p["mlp"], hn, cfg)
    if cache is not None:
        return out, {"k": new_cache["k"], "v": new_cache["v"]}
    if return_kv:
        return out, {"k": kv[0], "v": kv[1]}
    return out, None


class RecurrentGemmaLM:
    """Scan over (R, R, A) periods + unrolled tail."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg.validate()
        period = len(cfg.block_pattern)
        self.num_periods = cfg.num_layers // period
        self.tail = cfg.num_layers - self.num_periods * period  # leading-tail blocks

    def period_spec(self) -> Params:
        cfg = self.cfg
        out: Params = {}
        for idx, kind in enumerate(cfg.block_pattern):
            out[f"b{idx}"] = (
                spec_rglru_block(cfg) if kind == "recurrent" else spec_attn_block(cfg)
            )
        return out

    def param_specs(self) -> Params:
        cfg = self.cfg
        specs: Params = {
            "embed": L.spec_embedding(cfg),
            "periods": _stack_specs(self.period_spec(), self.num_periods),
            "final_norm": L.spec_rmsnorm(cfg),
            "unembed": L.spec_unembed(cfg),
        }
        for i in range(self.tail):
            kind = cfg.block_pattern[i % len(cfg.block_pattern)]
            specs[f"tail{i}"] = (
                spec_rglru_block(cfg) if kind == "recurrent" else spec_attn_block(cfg)
            )
        return specs

    def _window_len(self, max_len: int) -> int:
        return min(max_len, self.cfg.local_window)

    def cache_spec(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        w = cfg.lru_width or cfg.d_model
        t = self._window_len(max_len)
        dt = jnp.dtype(cfg.compute_dtype)
        per: Params = {}
        for idx, kind in enumerate(cfg.block_pattern):
            if kind == "recurrent":
                per[f"b{idx}"] = {
                    "conv": ParamSpec(
                        (self.num_periods, batch, cfg.conv_width - 1, w),
                        ("layers", "batch", None, "mlp"), dt, "zeros",
                    ),
                    "h": ParamSpec(
                        (self.num_periods, batch, w),
                        ("layers", "batch", "mlp"), jnp.float32, "zeros",
                    ),
                }
            else:
                kvs = (self.num_periods, batch, t, cfg.num_kv_heads, cfg.resolved_head_dim)
                per[f"b{idx}"] = {
                    "k": ParamSpec(kvs, ("layers", "batch", "kv_seq", "kv_heads", None), dt, "zeros"),
                    "v": ParamSpec(kvs, ("layers", "batch", "kv_seq", "kv_heads", None), dt, "zeros"),
                }
        spec: Params = {"periods": per, "len": ParamSpec((), (), jnp.int32, "zeros")}
        for i in range(self.tail):
            kind = self.cfg.block_pattern[i % len(self.cfg.block_pattern)]
            if kind == "recurrent":
                spec[f"tail{i}"] = {
                    "conv": ParamSpec((batch, cfg.conv_width - 1, w), ("batch", None, "mlp"), dt, "zeros"),
                    "h": ParamSpec((batch, w), ("batch", "mlp"), jnp.float32, "zeros"),
                }
            else:
                kvs = (batch, t, cfg.num_kv_heads, cfg.resolved_head_dim)
                spec[f"tail{i}"] = {
                    "k": ParamSpec(kvs, ("batch", "kv_seq", "kv_heads", None), dt, "zeros"),
                    "v": ParamSpec(kvs, ("batch", "kv_seq", "kv_heads", None), dt, "zeros"),
                }
        return spec

    def _apply_period(self, pp, h, cfg, caches=None, cache_len=None, return_state=False):
        new_caches: Params = {}
        for idx, kind in enumerate(cfg.block_pattern):
            key = f"b{idx}"
            c = None if caches is None else caches[key]
            if kind == "recurrent":
                h, nc = recurrent_block(pp[key], h, cfg, c, return_state=return_state)
            else:
                h, nc = local_attn_block(
                    pp[key], h, cfg, c, cache_len=cache_len, return_kv=return_state
                )
            if nc is not None:
                new_caches[key] = nc
        return h, (new_caches if new_caches else None)

    def _run(self, params, x, caches=None, cache_len=None, return_state=False):
        cfg = self.cfg

        def body(carry, xs):
            h, nc = self._apply_period(
                xs["p"], carry, cfg,
                None if caches is None else xs["c"],
                cache_len=cache_len, return_state=return_state,
            )
            return h, nc

        if cfg.remat:
            body = jax.checkpoint(body)
        xs: Params = {"p": params["periods"]}
        if caches is not None:
            xs["c"] = caches["periods"]
        h, new_period_caches = L.scan_blocks(body, x, xs)

        new_tail: Params = {}
        for i in range(self.tail):
            kind = cfg.block_pattern[i % len(cfg.block_pattern)]
            c = None if caches is None else caches[f"tail{i}"]
            if kind == "recurrent":
                h, nc = recurrent_block(params[f"tail{i}"], h, cfg, c, return_state=return_state)
            else:
                h, nc = local_attn_block(
                    params[f"tail{i}"], h, cfg, c, cache_len=cache_len, return_kv=return_state
                )
            if nc is not None:
                new_tail[f"tail{i}"] = nc
        return h, new_period_caches, new_tail

    def forward(self, params: Params, tokens: jax.Array, **_) -> jax.Array:
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg)
        h, _, _ = self._run(params, x)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return L.unembed(params["unembed"], h, cfg, params["embed"])

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        return cross_entropy(self.forward(params, batch["tokens"]), batch["labels"])

    def prefill(self, params: Params, tokens: jax.Array, max_len: int, **_):
        cfg = self.cfg
        b, t = tokens.shape
        x = L.embed(params["embed"], tokens, cfg)
        h, per_caches, tail_caches = self._run(params, x, return_state=True)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = L.unembed(params["unembed"], h[:, -1:], cfg, params["embed"])

        wlen = self._window_len(max_len)

        def fit_kv(c):
            if "k" not in c:
                return c
            k, v = c["k"], c["v"]
            kk, vv = L.fit_window_cache(k, v, k.ndim - 3, wlen, t)
            return {"k": kk, "v": vv}

        cache: Params = {
            "periods": {
                key: fit_kv(val) if "k" in val else val
                for key, val in (per_caches or {}).items()
            },
            "len": jnp.asarray(t, jnp.int32),
        }
        for key, val in tail_caches.items():
            cache[key] = fit_kv(val) if "k" in val else val
        return logits, cache

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg)
        # ring caches store absolute-position-rotated keys; len drives rope
        caches = {"periods": cache["periods"]}
        for i in range(self.tail):
            caches[f"tail{i}"] = cache[f"tail{i}"]
        h, new_per, new_tail = self._run(
            params, x, caches=caches, cache_len=cache["len"]
        )
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = L.unembed(params["unembed"], h, cfg, params["embed"])
        new_cache: Params = {"periods": new_per, "len": cache["len"] + tokens.shape[1]}
        new_cache.update(new_tail)
        return logits, new_cache
