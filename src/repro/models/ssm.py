"""Mamba-2 (SSD — state-space duality) language model.

Attention-free: the paper's softmax engine is inapplicable to the mixer
(DESIGN.md §5) — this arch exercises the framework's substrate instead.
The chunked SSD algorithm mirrors the blocked attention pipeline: intra-
chunk quadratic part + inter-chunk recurrent state, scanned over chunks.

Shapes: d_inner = expand*d_model, H = d_inner/headdim heads, state N,
ngroups G = 1 (B/C shared across heads).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import with_logical_constraint as wlc
from repro.models import layers as L
from repro.models.param import ParamSpec
from repro.models.transformer import _stack_specs, cross_entropy

Params = Dict[str, Any]


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return d_inner, heads, conv_dim


def spec_mamba_block(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner, heads, conv_dim = _dims(cfg)
    gn = cfg.ssm_ngroups * cfg.ssm_state
    pd = L.pdtype(cfg)
    return {
        "ln": L.spec_rmsnorm(cfg),
        "in_proj": ParamSpec(
            (d, 2 * d_inner + 2 * gn + heads), ("embed", "mlp"), pd, "fan_in"
        ),
        "conv": L.spec_conv1d(cfg, conv_dim, cfg.ssm_conv),
        "A_log": ParamSpec((heads,), (None,), pd, "zeros"),
        "D": ParamSpec((heads,), (None,), pd, "ones"),
        "dt_bias": ParamSpec((heads,), (None,), pd, "zeros"),
        "out_norm": ParamSpec((d_inner,), ("mlp",), pd, "ones"),
        "out_proj": ParamSpec((d_inner, d), ("mlp", "embed"), pd, "fan_in"),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d_inner, heads, _ = _dims(cfg)
    gn = cfg.ssm_ngroups * cfg.ssm_state
    z, x, bc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + 2 * gn], axis=-1
    )
    bmat, cmat = jnp.split(bc, 2, axis=-1)
    return z, x, bmat, cmat, dt


def _ssd_chunk_scan(
    x: jax.Array,  # [B, T, H, P] (pre-multiplied by dt)
    a: jax.Array,  # [B, T, H] log-decay (negative)
    bmat: jax.Array,  # [B, T, N]
    cmat: jax.Array,  # [B, T, N]
    h0: Optional[jax.Array],  # [B, H, N, P] initial state or None
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y [B,T,H,P], final state [B,H,N,P])."""
    b, t, h, p = x.shape
    n = bmat.shape[-1]
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = (t + pad) // chunk

    xc = x.reshape(b, nc, chunk, h, p).swapaxes(0, 1)  # [nc, B, Q, H, P]
    ac = a.reshape(b, nc, chunk, h).swapaxes(0, 1)
    bc_ = bmat.reshape(b, nc, chunk, n).swapaxes(0, 1)
    cc_ = cmat.reshape(b, nc, chunk, n).swapaxes(0, 1)

    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))  # q >= k

    def body(hprev, xs):
        xq, aq, bq, cq = xs  # [B,Q,H,P], [B,Q,H], [B,Q,N], [B,Q,N]
        xq = xq.astype(jnp.float32)
        ca = jnp.cumsum(aq.astype(jnp.float32), axis=1)  # inclusive [B,Q,H]
        last = ca[:, -1, :]  # [B,H]
        scores = jnp.einsum("bqn,bkn->bqk", cq.astype(jnp.float32), bq.astype(jnp.float32))
        decay = jnp.exp(ca[:, :, None, :] - ca[:, None, :, :])  # [B,Q,K,H]
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, decay, xq)
        y_inter = jnp.einsum("bqn,bhnp->bqhp", cq.astype(jnp.float32), hprev)
        y_inter = y_inter * jnp.exp(ca)[..., None]
        s_c = jnp.einsum("bkn,bkhp,bkh->bhnp", bq.astype(jnp.float32), xq,
                         jnp.exp(last[:, None, :] - ca))
        hnew = hprev * jnp.exp(last)[:, :, None, None] + s_c
        return hnew, y_intra + y_inter

    from repro.core.scan_ctl import scan_or_unroll
    hfin, ys = scan_or_unroll(body, h0, (xc, ac, bc_, cc_))
    y = ys.swapaxes(0, 1).reshape(b, nc * chunk, h, p)[:, :t]
    return y, hfin


def mamba_mixer(
    p: Params,
    x_in: jax.Array,  # [B, T, D]
    cfg: ModelConfig,
    cache: Optional[Params] = None,  # {"conv": [B,W-1,convdim], "ssm": [B,H,N,P]}
    return_state: bool = False,  # prefill: chunk-scan but emit a cache
) -> Tuple[jax.Array, Optional[Params]]:
    dt_ = L.cdtype(cfg)
    d_inner, heads, conv_dim = _dims(cfg)
    pdim = cfg.ssm_headdim
    zxbcdt = jnp.einsum("btd,de->bte", x_in, p["in_proj"].astype(dt_))
    z, x, bmat, cmat, dtproj = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)
    conv_out, new_conv = L.causal_conv1d(
        p["conv"], conv_in, None if cache is None else cache["conv"]
    )
    if cache is None and return_state:
        new_conv = conv_in[:, -(cfg.ssm_conv - 1):, :]
    conv_out = jax.nn.silu(conv_out)
    x, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + bmat.shape[-1]], axis=-1)

    b, t = x.shape[0], x.shape[1]
    xh = x.reshape(b, t, heads, pdim)
    dt = jax.nn.softplus(
        dtproj.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # [B,T,H]
    a_decay = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt  # negative log-decay
    xdt = xh.astype(jnp.float32) * dt[..., None]

    # G=1: B/C shared across heads
    bm = bmat[..., : cfg.ssm_state]
    cm = cmat[..., : cfg.ssm_state]

    if cache is None:
        y, hfin = _ssd_chunk_scan(xdt, a_decay, bm, cm, None, cfg.ssm_chunk)
        new_cache = (
            {"conv": new_conv.astype(dt_), "ssm": hfin} if return_state else None
        )
    else:
        # decode: exact recurrence, t is small (usually 1)
        def step(h, xs):
            xdt_t, a_t, b_t, c_t = xs
            h = h * jnp.exp(a_t)[:, :, None, None] + jnp.einsum(
                "bn,bhp->bhnp", b_t, xdt_t
            )
            y_t = jnp.einsum("bn,bhnp->bhp", c_t, h)
            return h, y_t

        hfin, ys = jax.lax.scan(
            step,
            cache["ssm"].astype(jnp.float32),
            (xdt.swapaxes(0, 1), a_decay.swapaxes(0, 1),
             bm.astype(jnp.float32).swapaxes(0, 1), cm.astype(jnp.float32).swapaxes(0, 1)),
        )
        y = ys.swapaxes(0, 1)
        new_cache = {"conv": new_conv, "ssm": hfin.astype(jnp.float32)}

    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, t, d_inner).astype(dt_)
    y = y * jax.nn.silu(z)
    # gated RMSNorm
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + cfg.norm_eps) * p["out_norm"].astype(jnp.float32)).astype(dt_)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(dt_))
    return wlc(out, ("batch", "seq", "embed")), new_cache


class MambaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg.validate()

    def block_spec(self) -> Params:
        return spec_mamba_block(self.cfg)

    def param_specs(self) -> Params:
        cfg = self.cfg
        return {
            "embed": L.spec_embedding(cfg),
            "blocks": _stack_specs(self.block_spec(), cfg.num_layers),
            "final_norm": L.spec_rmsnorm(cfg),
            "unembed": L.spec_unembed(cfg),
        }

    def _run(self, params, x, caches=None):
        cfg = self.cfg

        def body(carry, xs):
            bp = xs["p"]
            hin = L.rmsnorm(bp["ln"], carry, cfg.norm_eps)
            out, new_c = mamba_mixer(bp, hin, cfg, None if caches is None else xs["c"])
            return carry + out, new_c

        if cfg.remat:
            body = jax.checkpoint(body)
        xs: Params = {"p": params["blocks"]}
        if caches is not None:
            xs["c"] = caches
        h, new_caches = L.scan_blocks(body, x, xs)
        return h, new_caches

    def forward(self, params: Params, tokens: jax.Array, **_) -> jax.Array:
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg)
        h, _ = self._run(params, x)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return L.unembed(params["unembed"], h, cfg, params["embed"])

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        return cross_entropy(self.forward(params, batch["tokens"]), batch["labels"])

    # -- serving: constant-size state cache ----------------------------------

    def cache_spec(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        d_inner, heads, conv_dim = _dims(cfg)
        return {
            "layers": {
                "conv": ParamSpec(
                    (cfg.num_layers, batch, cfg.ssm_conv - 1, conv_dim),
                    ("layers", "batch", None, "mlp"), jnp.dtype(cfg.compute_dtype), "zeros",
                ),
                "ssm": ParamSpec(
                    (cfg.num_layers, batch, heads, cfg.ssm_state, cfg.ssm_headdim),
                    ("layers", "batch", "heads", None, None), jnp.float32, "zeros",
                ),
            },
            "len": ParamSpec((), (), jnp.int32, "zeros"),
        }

    def prefill(self, params: Params, tokens: jax.Array, max_len: int, **_):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg)

        def body(carry, bp):
            hin = L.rmsnorm(bp["ln"], carry, cfg.norm_eps)
            out, new_c = mamba_mixer(bp, hin, cfg, None, return_state=True)
            return carry + out, new_c

        if cfg.remat:
            body = jax.checkpoint(body)
        h, states = L.scan_blocks(body, x, params["blocks"])
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = L.unembed(params["unembed"], h[:, -1:], cfg, params["embed"])
        cache = {
            "layers": states,
            "len": jnp.asarray(tokens.shape[1], jnp.int32),
        }
        return logits, cache

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array):
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg)

        def body(carry, xs):
            bp = xs["p"]
            hin = L.rmsnorm(bp["ln"], carry, cfg.norm_eps)
            out, new_c = mamba_mixer(bp, hin, cfg, xs["c"])
            return carry + out, new_c

        h, new_states = L.scan_blocks(body, x, {"p": params["blocks"], "c": cache["layers"]})
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = L.unembed(params["unembed"], h, cfg, params["embed"])
        return logits, {"layers": new_states, "len": cache["len"] + tokens.shape[1]}
