"""Declarative parameters: models declare shapes + logical axes; the runtime
decides realization (materialize for tests, ShapeDtypeStruct for dry-runs,
PartitionSpec for sharding).  This is what lets one model definition serve
smoke tests on 1 CPU device and 512-chip dry-runs unchanged.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """A parameter declaration: shape, logical axes, dtype, initializer."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)
    dtype: Any = jnp.float32
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed | small
    scale: float = 1.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} do not match shape {self.shape}")


def _init_one(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "fan_in":
        fan_in = spec.shape[0] if len(spec.shape) == 1 else int(np.prod(spec.shape[:-1]))
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * spec.scale * 0.02).astype(spec.dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape) * spec.scale).astype(spec.dtype)
    if spec.init == "small":
        return (jax.random.normal(key, spec.shape) * spec.scale * 1e-2).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def materialize(specs: PyTree, key: jax.Array) -> PyTree:
    """Turn a tree of ParamSpec into actual arrays (deterministic per-path)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def shape_tree(specs: PyTree) -> PyTree:
    """ShapeDtypeStruct stand-ins (no allocation) for dry-run lowering."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def count_params(specs: PyTree) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def axes_tree(specs: PyTree) -> PyTree:
    """The logical-axes tree (same structure), for sharding rules."""
    return jax.tree.map(
        lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def cast_tree(params: PyTree, dtype) -> PyTree:
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_cast, params)
