"""Model registry: family -> implementation class."""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.rglru import RecurrentGemmaLM
from repro.models.ssm import MambaLM
from repro.models.transformer import DecoderLM


def build_model(cfg: ModelConfig):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if fam == "ssm":
        return MambaLM(cfg)
    if fam == "hybrid":
        return RecurrentGemmaLM(cfg)
    if fam == "encdec":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {fam!r}")
