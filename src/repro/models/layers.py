"""Shared model building blocks (functional style: spec_* declares params,
apply-style functions consume them).  All attention flows through the STAR
softmax engine unless the config says otherwise.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

import dataclasses

from repro import ops
from repro.configs.base import ModelConfig
from repro.core import kvquant
from repro.distributed.sharding import with_logical_constraint as wlc
from repro.models.param import ParamSpec

Params = Dict[str, Any]


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Norms


def spec_rmsnorm(cfg: ModelConfig, dim: Optional[int] = None) -> Params:
    return {"scale": ParamSpec((dim or cfg.d_model,), ("embed",), pdtype(cfg), "ones")}


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def spec_layernorm(cfg: ModelConfig, dim: Optional[int] = None) -> Params:
    d = dim or cfg.d_model
    return {
        "scale": ParamSpec((d,), ("embed",), pdtype(cfg), "ones"),
        "bias": ParamSpec((d,), ("embed",), pdtype(cfg), "zeros"),
    }


def layernorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding


def spec_embedding(cfg: ModelConfig) -> Params:
    return {
        "table": ParamSpec(
            (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), pdtype(cfg), "embed"
        )
    }


def embed(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    out = jnp.take(p["table"].astype(cdtype(cfg)), tokens, axis=0)
    return wlc(out, ("batch", "seq", "embed"))


def spec_unembed(cfg: ModelConfig) -> Params:
    if cfg.tie_embeddings:
        return {}
    return {
        "kernel": ParamSpec(
            (cfg.d_model, cfg.padded_vocab), ("embed", "vocab"), pdtype(cfg), "fan_in"
        )
    }


def unembed(p: Params, x: jax.Array, cfg: ModelConfig, embed_params: Params) -> jax.Array:
    if cfg.tie_embeddings:
        kernel = embed_params["table"].astype(cdtype(cfg)).T
    else:
        kernel = p["kernel"].astype(cdtype(cfg))
    logits = jnp.einsum("...d,dv->...v", x, kernel)
    if cfg.padded_vocab != cfg.vocab_size:  # mask padding columns
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))
    return wlc(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [B, T, H, D] rotated by positions [B, T] (half-split convention)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, T, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: Tuple[int, ...]
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): positions [B, T, 3] = (t, h, w) ids;
    ``sections`` splits the half-dim into per-stream frequency bands."""
    import numpy as np

    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    # which positional stream (t/h/w) drives each frequency band — static
    stream = jnp.asarray(np.repeat(np.arange(len(sections)), sections))  # [half]
    pos = jnp.take(positions.astype(jnp.float32), stream, axis=-1)  # [B, T, half]
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(t0: int | jax.Array, length: int, d_model: int) -> jax.Array:
    """Classic sinusoidal table slice [length, d_model] (seamless enc-dec)."""
    pos = (jnp.arange(length) + t0)[:, None].astype(jnp.float32)
    half = d_model // 2
    div = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / half))
    ang = pos * div[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention block


def spec_attention(cfg: ModelConfig, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    pd = pdtype(cfg)
    p: Params = {
        "wq": ParamSpec((d, hq * hd), ("embed", "heads"), pd, "fan_in"),
        "wk": ParamSpec((d, hkv * hd), ("embed", "kv_heads"), pd, "fan_in"),
        "wv": ParamSpec((d, hkv * hd), ("embed", "kv_heads"), pd, "fan_in"),
        "wo": ParamSpec((hq * hd, d), ("heads", "embed"), pd, "fan_in"),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((hq * hd,), ("heads",), pd, "zeros")
        p["bk"] = ParamSpec((hkv * hd,), ("kv_heads",), pd, "zeros")
        p["bv"] = ParamSpec((hkv * hd,), ("kv_heads",), pd, "zeros")
    return p


def _project_qkv(p: Params, x: jax.Array, xkv: jax.Array, cfg: ModelConfig):
    dt = cdtype(cfg)
    hd = cfg.resolved_head_dim
    q = jnp.einsum("btd,dh->bth", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dh->bth", xkv, p["wk"].astype(dt))
    v = jnp.einsum("btd,dh->bth", xkv, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    b, tq = q.shape[0], q.shape[1]
    tk = k.shape[1]
    q = q.reshape(b, tq, cfg.num_heads, hd)
    k = k.reshape(b, tk, cfg.num_kv_heads, hd)
    v = v.reshape(b, tk, cfg.num_kv_heads, hd)
    return q, k, v


def attention_block(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    positions: Optional[jax.Array] = None,  # [B, T] or [B, T, 3] for M-RoPE
    sliding_window: Optional[int] = None,
    cache: Optional[Params] = None,  # {"k","v","len"} decode cache
    xkv: Optional[jax.Array] = None,  # cross-attention memory
    kv_valid_len: Optional[jax.Array] = None,
    use_rope: bool = True,
    paged_cache_t: Optional[int] = None,  # paged cache: logical row count
) -> Tuple[jax.Array, Optional[Params], Tuple[jax.Array, jax.Array]]:
    """Self- or cross-attention with optional KV cache.

    The cache comes in three shapes: a scalar-``len`` decode cache, a
    per-slot pool (``len`` is a ``[B]`` vector), and a *paged* pool —
    K/V are ``[num_blocks, block_size, Hkv, D]`` page pools plus a
    ``"tables"`` entry of per-slot block tables (``repro.serve.paged``),
    with ``paged_cache_t`` carrying the logical per-slot row count (a
    static int: it sizes the gathered view and the ring modulo).

    Returns ``(out, cache', (k, v))`` — the fresh (rotated) K/V of this call
    so prefill can prime caches without recomputing projections."""
    b, tq, _ = x.shape
    q, k, v = _project_qkv(p, x, x if xkv is None else xkv, cfg)

    if use_rope and xkv is None:
        if positions is None:
            base = cache["len"] if cache is not None else 0
            if jnp.ndim(base) == 1:
                # the pool's rope counters live in the model-level cache
                # ("pos", which diverges from "len" for VLM); this layer
                # cannot reconstruct them from "len" alone
                raise ValueError(
                    "per-slot caches require explicit positions "
                    "(decode_step builds them from the pool's 'pos' counters)"
                )
            positions = base + jnp.arange(tq)[None, :].astype(jnp.int32)
            positions = jnp.broadcast_to(positions, (b, tq))
        if cfg.mrope_sections and positions.ndim == 3:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            if positions.ndim == 3:
                positions = positions[..., 0]
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    if cfg.seq_parallel_activations and tq > 1:
        # heads that don't divide the model axis (e.g. 28, 56) leave the
        # score tensor replicated and XLA all-reduces partial products per
        # KV block; sharding the q/score ROWS over the model axis instead
        # keeps softmax row-local (§Perf prefill finding)
        q = wlc(q, ("batch", "act_seq", "heads", None))
    else:
        q = wlc(q, ("batch", "seq", "heads", None))
    q_offset: jax.Array | int = 0
    new_cache = None
    if cache is not None and "tables" in cache:
        # Paged slot pool (DESIGN.md §8): K/V live in a flat block pool,
        # per-slot block tables give each slot a ragged logical buffer.
        # Same contract as the dense per-slot path below — write the fresh
        # token at the slot's own depth, mask by per-slot valid length —
        # but the write is a block-indirected scatter and the read is a
        # table gather inside the paged_attention op.
        assert tq == 1, "paged cache only supports 1-token decode"
        assert paged_cache_t is not None, "paged cache requires paged_cache_t"
        cache_t = paged_cache_t
        bs = cache["k"].shape[1]
        tables = cache["tables"]
        ring = sliding_window is not None and cache_t <= sliding_window
        idx = cache["len"] % cache_t if ring else cache["len"]
        # free slots' counters regrow past their (scratch-only) tables; the
        # clip keeps the gather in range, their writes land in scratch
        col = jnp.clip(idx // bs, 0, tables.shape[1] - 1)
        blk = jnp.take_along_axis(tables, col[:, None], axis=1)[:, 0]
        row = idx % bs
        new_len = cache["len"] + 1
        kv_dtype = kvquant.dtype_of(cache["k"].dtype)
        if kv_dtype != "fp32":
            # Quantized pool (DESIGN.md §13): scatter *codes*, and stamp
            # the block's scale row only on the block's first write — later
            # rows reuse the stamp (clipped encode), so a block's codes
            # always decode through the scale they were written with.  On a
            # ring's second lap (len >= cache_t) the previous lap's rows
            # still decode through the existing stamp, so wrap never
            # restamps.
            krow = k[:, 0].astype(jnp.float32)  # [S, Hkv, D]
            vrow = v[:, 0].astype(jnp.float32)
            fresh = row == 0
            if ring:
                fresh = fresh & (cache["len"] < cache_t)
            k_sc = jnp.where(
                fresh[:, None],
                kvquant.row_scale(krow, kv_dtype),
                cache["k_scale"][blk],
            )
            v_sc = jnp.where(
                fresh[:, None],
                kvquant.row_scale(vrow, kv_dtype),
                cache["v_scale"][blk],
            )
            ck = cache["k"].at[blk, row].set(
                kvquant.encode(krow, k_sc[..., None], kv_dtype)
            )
            cv = cache["v"].at[blk, row].set(
                kvquant.encode(vrow, v_sc[..., None], kv_dtype)
            )
            ks_pages = cache["k_scale"].at[blk].set(k_sc)
            vs_pages = cache["v_scale"].at[blk].set(v_sc)
            new_cache = {
                "k": ck, "v": cv,
                "k_scale": ks_pages, "v_scale": vs_pages,
                "len": new_len,
            }
            kv_scales = (ks_pages, vs_pages)
        else:
            ck = cache["k"].at[blk, row].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[blk, row].set(v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv, "len": new_len}
            kv_scales = None
        kvl = jnp.minimum(new_len, cache_t) if ring else new_len
        spec = dataclasses.replace(
            cfg.paged_attention_spec, block_size=bs, kv_dtype=kv_dtype
        )
        ctx = ops.paged_attention(
            q, ck, cv, tables, spec, kv_valid_len=kvl, kv_len=cache_t,
            kv_scales=kv_scales,
        )
        return ctx.reshape(b, tq, -1), new_cache, (k, v)
    if cache is not None:
        cache_t = cache["k"].shape[1]
        # Per-slot serving pool: cache["len"] is a [B] vector — every slot
        # decodes at its own depth, so the write index and the valid-length
        # mask are per batch row (continuous batching, DESIGN.md §6).
        per_slot = jnp.ndim(cache["len"]) == 1
        ring = sliding_window is not None and cache_t <= sliding_window
        if per_slot:
            assert tq == 1, "per-slot cache only supports 1-token decode"
            idx = cache["len"] % cache_t if ring else cache["len"]
            # blend-style write: dynamic_update_slice cannot take a
            # per-batch index, the one-hot hit mask can
            hit = (jnp.arange(cache_t)[None, :] == idx[:, None])[..., None, None]
            ck = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])
            k_full, v_full = ck, cv
            window_decode = ring
        elif ring:
            # ring-buffer for sliding windows, append otherwise
            assert tq == 1, "ring-buffer window cache only supports 1-token decode"
            idx = cache["len"] % cache_t
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
            # positions of ring slots are implicit; use unrotated ring order
            # only when tq == 1 (decode), which is the serving path.
            k_full, v_full = ck, cv
            window_decode = True
        elif cfg.kv_update == "onehot" and tq == 1:
            # sharding-friendly append: elementwise blend, no cross-shard
            # dynamic update (see ModelConfig.kv_update)
            hit = (jnp.arange(cache_t) == cache["len"])[None, :, None, None]
            ck = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])
            k_full, v_full = ck, cv
            window_decode = False
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, cache["len"], 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, cache["len"], 0, 0))
            k_full, v_full = ck, cv
            window_decode = False
        new_len = cache["len"] + tq
        new_cache = {"k": ck, "v": cv, "len": new_len}
        k_full = wlc(k_full, ("batch", "kv_seq", "kv_heads", None))
        v_full = wlc(v_full, ("batch", "kv_seq", "kv_heads", None))
        if window_decode or per_slot:
            # Single-token decode: the causal constraint is exactly "attend
            # to the first new_len cache rows", so a (per-batch) valid-length
            # mask subsumes it.  Ring caches additionally clamp to the window
            # capacity — slots >= len are zeros until the ring wraps.
            kvl = jnp.minimum(new_len, cache_t) if window_decode else new_len
            kvl = jnp.broadcast_to(kvl, (b,))
            out = _run_attention(
                q, k_full, v_full, cfg,
                causal=False, sliding_window=None, q_offset=0,
                kv_valid_len=kvl,
            )
            return out, new_cache, (k, v)
        q_offset = cache["len"]
        fresh_k, fresh_v = k, v
        k, v = k_full, v_full
        kv_valid_len = jnp.broadcast_to(new_len, (b,))
    else:
        fresh_k, fresh_v = k, v

    out = _run_attention(
        q, k, v, cfg,
        causal=causal and xkv is None,
        sliding_window=sliding_window,
        q_offset=q_offset,
        kv_valid_len=kv_valid_len,
    )
    return out, new_cache, (fresh_k, fresh_v)


def _run_attention(
    q, k, v, cfg: ModelConfig, *, causal, sliding_window, q_offset, kv_valid_len
) -> jax.Array:
    # One dispatch for every backend (repro.ops): the config carries the
    # static contract (impl, softmax engine, blocking), the call site only
    # supplies the per-invocation masking.  Decode-vs-prefill selection
    # (scan blocks only for long prefill rows — the §Perf decode finding)
    # lives inside the "xla" backend.
    ctx = ops.attention(
        q, k, v, cfg.attention_spec,
        causal=causal,
        sliding_window=sliding_window,
        q_offset=q_offset,
        kv_valid_len=kv_valid_len,
    )
    b, tq = ctx.shape[0], ctx.shape[1]
    return ctx.reshape(b, tq, -1)


def attention_out(p: Params, ctx: jax.Array, cfg: ModelConfig) -> jax.Array:
    out = jnp.einsum("bth,hd->btd", ctx, p["wo"].astype(cdtype(cfg)))
    return wlc(out, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# MLP


def spec_mlp(cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pd = pdtype(cfg)
    if cfg.mlp_type == "swiglu":
        return {
            "wi": ParamSpec((d, f), ("embed", "mlp"), pd, "fan_in"),
            "wg": ParamSpec((d, f), ("embed", "mlp"), pd, "fan_in"),
            "wo": ParamSpec((f, d), ("mlp", "embed"), pd, "fan_in"),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp"), pd, "fan_in"),
        "wo": ParamSpec((f, d), ("mlp", "embed"), pd, "fan_in"),
    }


def mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = cdtype(cfg)
    h = jnp.einsum("btd,df->btf", x, p["wi"].astype(dt))
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("btd,df->btf", x, p["wg"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = wlc(h, ("batch", "seq", "mlp"))
    out = jnp.einsum("btf,fd->btd", h, p["wo"].astype(dt))
    return wlc(out, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# MoE (granite-moe: EP over 32 experts; mixtral: TP over 8 experts)


def spec_moe(cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pd = pdtype(cfg)
    return {
        "router": ParamSpec((d, e), ("embed", None), pd, "fan_in"),
        "wi": ParamSpec((e, d, f), ("expert", "embed", "mlp"), pd, "fan_in"),
        "wg": ParamSpec((e, d, f), ("expert", "embed", "mlp"), pd, "fan_in"),
        "wo": ParamSpec((e, f, d), ("expert", "mlp", "embed"), pd, "fan_in"),
    }


def moe_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    """Per-expert queue capacity for a ``tokens_per_group``-token call.

    Factored out because capacity is *shape-dependent*: chunked prefill must
    pass the capacity of the **full** sequence into every chunk (plus the
    carried queue counts, see ``moe(state=...)``) or token-dropping decisions
    — and therefore the outputs — would differ from a monolithic prefill.
    """
    return max(1, int(cfg.capacity_factor * cfg.top_k * tokens_per_group / cfg.num_experts))


def moe(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: Optional[jax.Array] = None,
    capacity: Optional[int] = None,
):
    """Grouped one-hot dispatch MoE (GShard-style, capacity-dropped).

    The router softmax runs through the STAR engine when cfg.star_router —
    the paper's point (softmax precision-insensitivity) applies to routing
    distributions at least as well as to attention.

    ``state`` / ``capacity`` make the capacity-dropping decision
    *chunk-invariant* for chunked prefill: ``state`` ([groups, experts]
    int32) carries per-expert assignment counts from earlier chunks of the
    same sequence (so queue positions are global, not per-call), and
    ``capacity`` overrides the per-call queue bound with the full-sequence
    one.  When either is given the call returns ``(y, new_state)``; the
    bare-``y`` legacy form (both None) is bit-identical to the historical
    behavior.  Every (token, choice) occupies its *global* queue position,
    so chunk-wise outputs match the monolithic pass exactly: the expert FFN
    is row-independent and the combine weights select identical rows.
    """
    dt = cdtype(cfg)
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    tokens = b * t
    groups = b  # one group per batch row keeps dispatch O(T^2/G) local
    tg = tokens // groups
    xg = x.reshape(groups, tg, d)
    stateful = state is not None or capacity is not None

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(dt)).astype(jnp.float32)
    spec = cfg.softmax_spec
    if not cfg.star_router:
        spec = dataclasses.replace(spec, kind="exact")
    if spec.kind == "exact":
        # exact routing distribution: the pallas engine is star-only, so
        # route the oracle through reference rather than a capability error
        spec = dataclasses.replace(spec, impl="reference")
    probs = ops.softmax(logits, spec)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [g, t, k]
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    cap = capacity if capacity is not None else moe_capacity(cfg, tg)
    # position of each (token, choice) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # [g, t, k, e]
    flat = onehot.reshape(groups, tg * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(groups, tg, k, e)
    if state is not None:
        # offset intra-call positions by the prior chunks' per-expert
        # counts so position == global queue position for this sequence
        pos = pos + state.astype(jnp.float32)[:, None, None, :]
    pos = jnp.sum(pos * onehot, axis=-1)  # [g, t, k]
    keep = pos < cap
    gate_vals = gate_vals * keep

    # dispatch [g, t, e, cap] combine weights
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)  # [g,t,k,cap]
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot * keep[..., None], pos_oh)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, pos_oh, gate_vals)

    xin = jnp.einsum("gtec,gtd->egcd", dispatch, xg.astype(jnp.float32)).astype(dt)
    xin = wlc(xin, ("expert", "batch", None, "embed"))
    h = jnp.einsum("egcd,edf->egcf", xin, p["wi"].astype(dt))
    g_ = jnp.einsum("egcd,edf->egcf", xin, p["wg"].astype(dt))
    h = jax.nn.silu(g_) * h
    h = wlc(h, ("expert", "batch", None, "mlp"))
    out = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(dt))
    out = wlc(out, ("expert", "batch", None, "embed"))
    y = jnp.einsum("gtec,egcd->gtd", combine.astype(dt), out)
    y = wlc(y.reshape(b, t, d), ("batch", "seq", "embed"))
    if not stateful:
        return y
    # counts include dropped choices — the monolithic cumsum does too
    counts = jnp.sum(onehot, axis=(1, 2)).astype(jnp.int32)  # [g, e]
    new_state = counts if state is None else state + counts
    return y, new_state


def scan_blocks(body, carry, xs, use_scan: bool = True):
    """lax.scan over stacked block params; unrolls under the dry-run cost
    probe context (see core.scan_ctl) or when use_scan=False."""
    from repro.core.scan_ctl import scan_or_unroll, unroll_scans_enabled

    if use_scan and not unroll_scans_enabled():
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def fit_window_cache(k: jax.Array, v: jax.Array, seq_axis: int, wlen: int, seq_len: int):
    """Trim prefill K/V to a ``wlen`` ring cache with slot = position % wlen.

    Decode inserts at ``len % wlen``, so the kept window must be *rolled* so
    token ``j`` sits at slot ``j % wlen`` — a plain "keep last wlen" layout
    would be overwritten in the wrong order.
    """
    seq = k.shape[seq_axis]
    assert seq == seq_len
    if seq >= wlen:
        sl = [slice(None)] * k.ndim
        sl[seq_axis] = slice(seq - wlen, seq)
        kk, vv = k[tuple(sl)], v[tuple(sl)]
        shift = (seq_len - wlen) % wlen
        kk = jnp.roll(kk, shift, axis=seq_axis)
        vv = jnp.roll(vv, shift, axis=seq_axis)
        return kk, vv
    pad = [(0, 0)] * k.ndim
    pad[seq_axis] = (0, wlen - seq)
    return jnp.pad(k, pad), jnp.pad(v, pad)


# ---------------------------------------------------------------------------
# Causal depthwise conv (mamba2 / recurrentgemma)


def spec_conv1d(cfg: ModelConfig, channels: int, width: int) -> Params:
    return {"kernel": ParamSpec((width, channels), ("conv", "mlp"), pdtype(cfg), "fan_in")}


def causal_conv1d(
    p: Params, x: jax.Array, state: Optional[jax.Array] = None
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Depthwise causal conv.  x [B, T, C]; state [B, W-1, C] carries context
    for decode.  Returns (y, new_state)."""
    w = p["kernel"].astype(x.dtype)  # [W, C]
    width = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
        new_state = None
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(width - 1):, :]
    y = sum(
        xp[:, i : xp.shape[1] - (width - 1 - i), :] * w[i]
        for i in range(width)
    )
    return y, new_state
