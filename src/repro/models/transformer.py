"""Decoder-only transformer LM: dense, MoE, and VLM variants.

One definition serves granite-8b, qwen2-72b, deepseek-coder-33b,
llama3-405b (dense), granite-moe / mixtral (MoE), and qwen2-vl (VLM
backbone with stub patch embeddings + M-RoPE).

Layers are scan-stacked (``cfg.scan_layers``) so XLA compiles ONE block and
loops it — essential for the 512-device dry-runs — with per-block remat.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import kvquant
from repro.distributed.sharding import with_logical_constraint as wlc
from repro.models import layers as L
from repro.models.param import ParamSpec

Params = Dict[str, Any]


def _stack_specs(spec: Params, n: int) -> Params:
    """Prepend a 'layers' axis to every ParamSpec in a block spec tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype, s.init, s.scale),
        spec,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg.validate()

    # -- parameters ---------------------------------------------------------

    def block_spec(self) -> Params:
        cfg = self.cfg
        spec: Params = {
            "ln1": L.spec_rmsnorm(cfg),
            "attn": L.spec_attention(cfg),
            "ln2": L.spec_rmsnorm(cfg),
        }
        if cfg.family == "moe":
            spec["moe"] = L.spec_moe(cfg)
        else:
            spec["mlp"] = L.spec_mlp(cfg)
        return spec

    def param_specs(self) -> Params:
        cfg = self.cfg
        specs: Params = {
            "embed": L.spec_embedding(cfg),
            "blocks": _stack_specs(self.block_spec(), cfg.num_layers),
            "final_norm": L.spec_rmsnorm(cfg),
            "unembed": L.spec_unembed(cfg),
        }
        if cfg.family == "vlm":
            specs["patch_proj"] = {
                "kernel": ParamSpec(
                    (cfg.frontend_dim or cfg.d_model, cfg.d_model),
                    ("embed", None), jnp.dtype(cfg.param_dtype), "fan_in",
                )
            }
        return specs

    # -- block --------------------------------------------------------------

    def _block(
        self,
        bp: Params,
        h: jax.Array,
        *,
        positions: Optional[jax.Array],
        cache: Optional[Params],
        kv_valid_len: Optional[jax.Array],
        paged_cache_t: Optional[int] = None,
        moe_capacity: Optional[int] = None,
    ) -> Tuple[jax.Array, Optional[Params], Tuple[jax.Array, jax.Array], Optional[jax.Array]]:
        cfg = self.cfg
        a, new_cache, kv = L.attention_block(
            bp["attn"], L.rmsnorm(bp["ln1"], h, cfg.norm_eps), cfg,
            causal=True, positions=positions,
            sliding_window=cfg.sliding_window, cache=cache,
            kv_valid_len=kv_valid_len, paged_cache_t=paged_cache_t,
        )
        h = h + L.attention_out(bp["attn"], a, cfg)
        hn = L.rmsnorm(bp["ln2"], h, cfg.norm_eps)
        moe_state = None
        if cfg.family == "moe":
            prior = cache.get("moe") if cache is not None else None
            if prior is not None or moe_capacity is not None:
                # chunked prefill: global expert-queue positions + the
                # full-sequence capacity keep dropping chunk-invariant
                y, moe_state = L.moe(bp["moe"], hn, cfg, state=prior, capacity=moe_capacity)
                h = h + y
            else:
                h = h + L.moe(bp["moe"], hn, cfg)
        else:
            h = h + L.mlp(bp["mlp"], hn, cfg)
        return h, new_cache, kv, moe_state

    def _run_blocks(
        self,
        params: Params,
        h: jax.Array,
        *,
        positions: Optional[jax.Array] = None,
        caches: Optional[Params] = None,
        kv_valid_len: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Optional[Params]]:
        cfg = self.cfg

        def body(carry, xs):
            bp = xs["p"]
            cache = xs.get("c")
            out, new_cache, _, _ = self._block(
                bp, carry, positions=positions, cache=cache,
                kv_valid_len=kv_valid_len,
            )
            if cfg.seq_parallel_activations:
                # shard the inter-block carry's seq dim over the model axis —
                # the remat-saved residual per layer shrinks by the TP degree
                out = wlc(out, ("batch", "act_seq", "embed"))
            return out, new_cache

        if cfg.remat:
            body = jax.checkpoint(body)

        if cfg.scan_layers:
            xs: Params = {"p": params["blocks"]}
            if caches is not None:
                xs["c"] = caches
            h, new_caches = L.scan_blocks(body, h, xs)
            return h, new_caches
        # unrolled (debug path)
        new_caches = []
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda x: x[i], params["blocks"])
            xs = {"p": bp}
            if caches is not None:
                xs["c"] = jax.tree.map(lambda x: x[i], caches)
            h, nc = body(h, xs)
            new_caches.append(nc)
        if caches is not None:
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        else:
            new_caches = None
        return h, new_caches

    # -- embedding helpers ----------------------------------------------------

    def _embed_inputs(
        self, params: Params, tokens: jax.Array, patch_embeds: Optional[jax.Array]
    ) -> Tuple[jax.Array, Optional[jax.Array], int]:
        """Returns (x, positions, n_prefix).  VLM prepends projected patches
        and builds M-RoPE (t, h, w) position ids; text uses 1-D positions."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg)
        b = tokens.shape[0]
        if cfg.family != "vlm" or patch_embeds is None:
            return x, None, 0
        dt = L.cdtype(cfg)
        patches = jnp.einsum(
            "bpd,dm->bpm", patch_embeds.astype(dt), params["patch_proj"]["kernel"].astype(dt)
        )
        n_patch = patches.shape[1]
        x = jnp.concatenate([patches, x], axis=1)
        # M-RoPE ids — patches: t=0, (h, w) on a stub grid; text: all equal,
        # offset past the patch grid extent.
        side = max(1, int(n_patch ** 0.5))
        hh = (jnp.arange(n_patch) // side).astype(jnp.int32)
        ww = (jnp.arange(n_patch) % side).astype(jnp.int32)
        ppos = jnp.stack([jnp.zeros_like(hh), hh, ww], axis=-1)  # [P, 3]
        t0 = side  # text starts after patch grid extent (qwen2-vl convention)
        tpos1 = t0 + jnp.arange(tokens.shape[1], dtype=jnp.int32)
        tpos = jnp.stack([tpos1, tpos1, tpos1], axis=-1)  # [T, 3]
        pos = jnp.concatenate([ppos, tpos], axis=0)[None]  # [1, P+T, 3]
        return x, jnp.broadcast_to(pos, (b,) + pos.shape[1:]), n_patch

    # -- public API -----------------------------------------------------------

    def forward(
        self,
        params: Params,
        tokens: jax.Array,
        *,
        patch_embeds: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Full-sequence causal forward -> logits [B, T(+P), V]."""
        cfg = self.cfg
        x, positions, _ = self._embed_inputs(params, tokens, patch_embeds)
        h, _ = self._run_blocks(params, x, positions=positions)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return L.unembed(params["unembed"], h, cfg, params["embed"])

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        """Mean next-token CE.  batch: tokens [B,T], labels [B,T] (-1 = pad),
        optional patch_embeds."""
        logits = self.forward(
            params, batch["tokens"], patch_embeds=batch.get("patch_embeds")
        )
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:  # VLM prefix: no loss on patches
            logits = logits[:, logits.shape[1] - labels.shape[1]:]
        return cross_entropy(logits, labels)

    # -- serving --------------------------------------------------------------

    def cache_len(self, max_len: int) -> int:
        if self.cfg.sliding_window is not None:
            return min(max_len, self.cfg.sliding_window)
        return max_len

    def cache_spec(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        t = self.cache_len(max_len)
        kv = (cfg.num_layers, batch, t, cfg.num_kv_heads, cfg.resolved_head_dim)
        axes = ("layers", "batch", "kv_seq", "kv_heads", None)
        dt = jnp.dtype(cfg.compute_dtype)
        return {
            "layers": {
                "k": ParamSpec(kv, axes, dt, "zeros"),
                "v": ParamSpec(kv, axes, dt, "zeros"),
            },
            "len": ParamSpec((), (), jnp.int32, "zeros"),
            # rope position of the next token — differs from "len" for VLM
            # (M-RoPE positions restart after the patch grid extent)
            "pos": ParamSpec((), (), jnp.int32, "zeros"),
        }

    # -- slot-pool serving (continuous batching) ------------------------------
    #
    # A slot pool is an ordinary decode cache whose "len"/"pos" entries are
    # [num_slots] vectors instead of scalars: each batch row ("slot") decodes
    # at its own depth.  ``decode_step`` handles both forms transparently
    # (see layers.attention_block's per-slot path); the helpers below manage
    # slot lifecycle for repro.serve.  DESIGN.md §6 documents the dataflow.

    def init_pool_cache(self, num_slots: int, max_len: int) -> Params:
        """Zeroed slot-pool cache: KV [L, S, T, Hkv, D], per-slot len/pos."""
        cfg = self.cfg
        t = self.cache_len(max_len)
        kv = (cfg.num_layers, num_slots, t, cfg.num_kv_heads, cfg.resolved_head_dim)
        dt = jnp.dtype(cfg.compute_dtype)
        return {
            "layers": {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt)},
            "len": jnp.zeros((num_slots,), jnp.int32),
            "pos": jnp.zeros((num_slots,), jnp.int32),
        }

    def write_slot(self, pool: Params, cache: Params, slot: int) -> Params:
        """Insert a single-request prefill cache (batch 1) into pool ``slot``.

        The prefill must have used the pool's ``max_len`` so the cache seq
        dims line up; the freshly admitted request starts decoding at its
        own length on the next pool tick.
        """
        k1 = cache["layers"]["k"]
        pk = pool["layers"]["k"]
        if k1.shape[1] != 1:
            raise ValueError(f"write_slot expects a batch-1 prefill cache, got {k1.shape}")
        if k1.shape[2] != pk.shape[2]:
            raise ValueError(
                f"prefill cache length {k1.shape[2]} != pool length {pk.shape[2]}; "
                "prefill with the pool's max_len"
            )
        return {
            "layers": {
                "k": pk.at[:, slot].set(k1[:, 0].astype(pk.dtype)),
                "v": pool["layers"]["v"].at[:, slot].set(
                    cache["layers"]["v"][:, 0].astype(pk.dtype)
                ),
            },
            "len": pool["len"].at[slot].set(cache["len"].astype(jnp.int32)),
            "pos": pool["pos"].at[slot].set(cache["pos"].astype(jnp.int32)),
        }

    def reset_slot(self, pool: Params, slot: int) -> Params:
        """Retire ``slot``: zero its counters so its stale rows are masked.

        Note the counters regrow while the slot sits free — ``decode_step``
        advances the whole ``len`` vector every tick — so a free slot
        accumulates masked garbage that the next admission overwrites
        wholesale.  ``len == 0`` is NOT a free-slot predicate; the
        scheduler owns slot occupancy."""
        return {
            "layers": pool["layers"],
            "len": pool["len"].at[slot].set(0),
            "pos": pool["pos"].at[slot].set(0),
        }

    # -- paged slot pool (block-table KV cache) -------------------------------
    #
    # The paged pool replaces each slot's dense [T] KV row with a block
    # table over a flat [num_blocks, block_size] page pool (DESIGN.md §8;
    # host allocator: repro.serve.paged.BlockPool).  Logical row i of a
    # slot lives at (table[i // bs], i % bs), so gathering a table
    # reproduces the dense row bit-for-bit — paged greedy decode is
    # token-identical to the dense pool by construction.

    def init_paged_cache(
        self, num_blocks: int, block_size: int, num_slots: int,
        kv_dtype: str = "fp32",
    ) -> Params:
        """Zeroed page pool: KV [L, N, bs, Hkv, D], per-slot len/pos.

        ``kv_dtype != "fp32"`` stores quantized codes instead of values and
        adds ``k_scale``/``v_scale`` leaves — one float32 scale per
        (layer, block, kv_head) — initialized to ones so the scratch block
        and never-written pages decode to exact zeros (DESIGN.md §13).
        fp32 pools carry *no* scale leaves: ``"k_scale" in cache["layers"]``
        is the quantized-layout marker everywhere downstream.
        """
        cfg = self.cfg
        kvquant.validate_kv_dtype(kv_dtype)
        kv = (
            cfg.num_layers, num_blocks, block_size,
            cfg.num_kv_heads, cfg.resolved_head_dim,
        )
        dt = (
            jnp.dtype(cfg.compute_dtype)
            if kv_dtype == "fp32"
            else kvquant.storage_dtype(kv_dtype)
        )
        leaves = {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt)}
        if kv_dtype != "fp32":
            sc = (cfg.num_layers, num_blocks, cfg.num_kv_heads)
            leaves["k_scale"] = jnp.ones(sc, jnp.float32)
            leaves["v_scale"] = jnp.ones(sc, jnp.float32)
        return {
            "layers": leaves,
            "len": jnp.zeros((num_slots,), jnp.int32),
            "pos": jnp.zeros((num_slots,), jnp.int32),
        }

    def write_slot_paged(
        self, pool: Params, cache: Params, slot: int, table: jax.Array
    ) -> Params:
        """Scatter a batch-1 prefill cache into the blocks of ``table``.

        The prefill rows are zero-padded up to the block grid, so a
        recycled block is overwritten *wholesale* — no stale rows from its
        previous owner survive inside the allocated table (rows past the
        grid are scratch and masked).  ``table`` is the [W] int32 block-id
        row the host allocator assigned to this request.
        """
        k1 = cache["layers"]["k"]
        pk = pool["layers"]["k"]
        if k1.shape[1] != 1:
            raise ValueError(f"write_slot_paged expects a batch-1 cache, got {k1.shape}")
        bs = pk.shape[2]
        w = table.shape[0]
        t1 = k1.shape[2]
        if t1 > w * bs:
            raise ValueError(
                f"prefill cache has {t1} rows but the table holds "
                f"{w} blocks x {bs} = {w * bs}"
            )
        pad = [(0, 0), (0, 0), (0, w * bs - t1), (0, 0), (0, 0)]

        def blocks(arr):  # [L, 1, T1, H, D] -> [L, W, bs, H, D]
            a = jnp.pad(arr, pad)[:, 0]
            lyr, _, h, d = a.shape
            return a.reshape(lyr, w, bs, h, d)

        kv_dtype = kvquant.dtype_of(pk.dtype)
        if kv_dtype != "fp32":
            # Prefill-time quantization: whole blocks at once, so each
            # block's scale is the true absmax over its rows — no clipping
            # on this path (DESIGN.md §13).
            kc, ks = kvquant.quantize_blocks(blocks(k1), kv_dtype)
            vc, vs = kvquant.quantize_blocks(blocks(cache["layers"]["v"]), kv_dtype)
            leaves = {
                "k": pk.at[:, table].set(kc),
                "v": pool["layers"]["v"].at[:, table].set(vc),
                "k_scale": pool["layers"]["k_scale"].at[:, table].set(ks),
                "v_scale": pool["layers"]["v_scale"].at[:, table].set(vs),
            }
        else:
            leaves = {
                "k": pk.at[:, table].set(blocks(k1).astype(pk.dtype)),
                "v": pool["layers"]["v"].at[:, table].set(
                    blocks(cache["layers"]["v"]).astype(pk.dtype)
                ),
            }
        return {
            "layers": leaves,
            "len": pool["len"].at[slot].set(cache["len"].astype(jnp.int32)),
            "pos": pool["pos"].at[slot].set(cache["pos"].astype(jnp.int32)),
        }

    def copy_block(self, pool: Params, src: jax.Array, dst: jax.Array) -> Params:
        """Copy one KV block (all layers) — the device half of the
        allocator's copy-on-fork hook (``BlockPool.ensure_writable``)."""
        pk, pv = pool["layers"]["k"], pool["layers"]["v"]
        leaves = {
            "k": pk.at[:, dst].set(pk[:, src]),
            "v": pv.at[:, dst].set(pv[:, src]),
        }
        for name in ("k_scale", "v_scale"):
            # quantized layout: the scale row shares its block's lifecycle,
            # so a CoW copy moves it too (DESIGN.md §13)
            if name in pool["layers"]:
                sp = pool["layers"][name]
                leaves[name] = sp.at[:, dst].set(sp[:, src])
        return {
            "layers": leaves,
            "len": pool["len"],
            "pos": pool["pos"],
        }

    def decode_step_paged(
        self,
        params: Params,
        cache: Params,
        tokens: jax.Array,
        block_tables: jax.Array,  # [S, W] int32 (host allocator state)
        *,
        cache_t: int,
    ) -> Tuple[jax.Array, Params]:
        """One paged token step.  tokens [S, 1] -> (logits [S, 1, V], cache').

        ``block_tables`` is per-tick host input (the allocator appends
        blocks between ticks); ``cache_t`` is the static logical per-slot
        row count (= ``cache_len(max_len)``) — it sizes the gathered view
        and the sliding-window ring modulo.
        """
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg)
        pos0 = cache.get("pos", cache["len"])
        pos = pos0.astype(jnp.int32)[:, None]  # [S, 1]
        if cfg.mrope_sections:
            pos = jnp.stack([pos, pos, pos], axis=-1)

        kv_leaves = tuple(cache["layers"])  # += k/v_scale when quantized

        def body(carry, xs):
            out, new_c, _, _ = self._block(
                xs["p"], carry, positions=pos,
                cache={**xs["c"], "len": cache["len"], "tables": block_tables},
                kv_valid_len=None, paged_cache_t=cache_t,
            )
            return out, {name: new_c[name] for name in kv_leaves}

        h, new_layer_caches = L.scan_blocks(
            body, x, {"p": params["blocks"], "c": cache["layers"]}
        )
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = L.unembed(params["unembed"], h, cfg, params["embed"])
        new_cache = {
            "layers": {name: new_layer_caches[name] for name in kv_leaves},
            "len": cache["len"] + 1,
            "pos": cache.get("pos", cache["len"]) + 1,
        }
        return logits, new_cache

    def prefill(
        self,
        params: Params,
        tokens: jax.Array,
        max_len: int,
        *,
        patch_embeds: Optional[jax.Array] = None,
        cache_t: Optional[int] = None,
        moe_capacity: Optional[int] = None,
    ) -> Tuple[jax.Array, Params]:
        """Process a prompt, return (last-position logits, primed cache).

        ``cache_t`` overrides the cache capacity (default
        ``cache_len(max_len)``) — chunked prefill stages into a *linear*
        buffer sized past the sliding window so later chunks can append
        (``prefill_extend``) before ``finalize_ring_cache`` folds it.
        ``moe_capacity`` threads the full-sequence expert capacity through
        (and adds per-layer ``moe`` queue counts to the returned cache) so
        a chunked MoE prefill drops exactly the tokens a monolithic one
        would.
        """
        cfg = self.cfg
        b, t = tokens.shape
        x, positions, n_prefix = self._embed_inputs(params, tokens, patch_embeds)

        def body(carry, bp):
            out, _, (k, v), ms = self._block(
                bp, carry, positions=positions, cache=None, kv_valid_len=None,
                moe_capacity=moe_capacity,
            )
            ys = {"k": k, "v": v}
            if ms is not None:
                ys["moe"] = ms
            return out, ys

        if cfg.remat:
            body = jax.checkpoint(body)
        h, kvs = L.scan_blocks(body, x, params["blocks"])
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = L.unembed(params["unembed"], h[:, -1:], cfg, params["embed"])

        ct = cache_t if cache_t is not None else self.cache_len(max_len)
        seq = x.shape[1]
        if cfg.sliding_window is None and seq > ct:
            raise ValueError(
                f"prefill length {seq} (incl. any patch prefix) exceeds cache "
                f"capacity {ct}; pass a larger max_len"
            )
        k_init, v_init = L.fit_window_cache(kvs["k"], kvs["v"], 2, ct, seq)
        if positions is not None:  # VLM: next M-RoPE temporal position
            next_pos = positions[0, -1, 0].astype(jnp.int32) + 1
        else:
            next_pos = jnp.asarray(seq, jnp.int32)
        layer_caches = {"k": k_init, "v": v_init}
        if "moe" in kvs:
            layer_caches["moe"] = kvs["moe"]
        cache = {
            "layers": layer_caches,
            "len": jnp.asarray(seq, jnp.int32),
            "pos": next_pos,
        }
        return logits, cache

    def prefill_extend(
        self,
        params: Params,
        cache: Params,
        tokens: jax.Array,
        *,
        moe_capacity: Optional[int] = None,
    ) -> Tuple[jax.Array, Params]:
        """Append a prompt chunk to a *linear* staging cache.

        tokens [1, c] land at rows ``[len, len+c)`` of the staging buffer
        (the attention append path: queries at offset ``len``, causal +
        sliding-window masking against every cached row), so running a
        prompt through ``prefill`` + ``prefill_extend`` chunks produces the
        same KV rows and final logits as one monolithic ``prefill`` — the
        bit-identity contract chunked serving relies on (DESIGN.md §12).
        Requires the staging buffer to be strictly longer than the sliding
        window (the ring in-place path only supports single-token writes).
        """
        cfg = self.cfg
        b, c = tokens.shape
        x = L.embed(params["embed"], tokens, cfg)
        pos0 = cache.get("pos", cache["len"]).astype(jnp.int32)
        pos = pos0 + jnp.arange(c, dtype=jnp.int32)[None]
        pos = jnp.broadcast_to(pos, (b, c))
        if cfg.mrope_sections:
            pos = jnp.stack([pos, pos, pos], axis=-1)

        def body(carry, xs):
            out, new_c, _, ms = self._block(
                xs["p"], carry, positions=pos,
                cache={**xs["c"], "len": cache["len"]},
                kv_valid_len=None, moe_capacity=moe_capacity,
            )
            ys = {"k": new_c["k"], "v": new_c["v"]}
            if ms is not None:
                ys["moe"] = ms
            return out, ys

        h, new_layers = L.scan_blocks(
            body, x, {"p": params["blocks"], "c": cache["layers"]}
        )
        # rmsnorm is positionwise, so norming the last row alone matches
        # the monolithic norm-then-slice bit for bit
        h = L.rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
        logits = L.unembed(params["unembed"], h, cfg, params["embed"])
        new_cache = {
            "layers": new_layers,
            "len": cache["len"] + c,
            "pos": cache.get("pos", cache["len"]) + c,
        }
        return logits, new_cache

    def gather_prefix_cache(
        self, pool: Params, blocks, rows: int, capacity: int
    ) -> Params:
        """Batch-1 linear staging cache seeded from cached prefix ``blocks``.

        The prefix-cache admission path: the trie matched ``rows`` prompt
        rows living in ``blocks`` (all full, ``rows == len(blocks) *
        block_size``), and the uncached suffix continues from there via
        ``prefill_extend``.  Rows past ``rows`` are zero until written —
        masked garbage, exactly like a monolithic prefill's padding.
        """
        pk, pv = pool["layers"]["k"], pool["layers"]["v"]
        bs = pk.shape[2]
        if rows != len(blocks) * bs:
            raise ValueError(f"prefix rows {rows} != {len(blocks)} blocks x {bs}")
        tab = jnp.asarray(list(blocks), jnp.int32)
        quantized = "k_scale" in pool["layers"]
        dt = jnp.dtype(self.cfg.compute_dtype)

        def gather(a, scale):  # [L, N, bs, H, D] -> [L, 1, capacity, H, D]
            g = a[:, tab]
            if scale is not None:
                # dense staging holds *values*: restore the cached prefix
                # blocks through their own scale rows (same codes * scale
                # expression the decode kernel evaluates — DESIGN.md §13)
                g = kvquant.decode(g, scale[:, tab][:, :, None, :, None]).astype(dt)
            lyr, w, _, hh, dd = g.shape
            g = g.reshape(lyr, 1, w * bs, hh, dd)
            return jnp.pad(g, [(0, 0), (0, 0), (0, capacity - w * bs), (0, 0), (0, 0)])

        rows32 = jnp.asarray(rows, jnp.int32)
        return {
            "layers": {
                "k": gather(pk, pool["layers"]["k_scale"] if quantized else None),
                "v": gather(pv, pool["layers"]["v_scale"] if quantized else None),
            },
            "len": rows32,
            "pos": rows32,
        }

    def finalize_ring_cache(self, cache: Params, wlen: int) -> Params:
        """Fold a linear staging cache into the ring layout (slot = pos % wlen).

        The traced-length counterpart of ``layers.fit_window_cache``: ring
        slot ``s`` receives the *latest* staged token congruent to ``s``
        (``j = s + floor((T-1-s)/wlen) * wlen``), with a traced ``T`` so
        chunk-count differences don't retrace.  Slots ``s >= T`` clip to
        row 0 — masked garbage, decode only trusts ``min(len, wlen)`` rows.
        """
        k = cache["layers"]["k"]
        T = cache["len"].astype(jnp.int32)
        s = jnp.arange(wlen, dtype=jnp.int32)
        j = jnp.clip(s + ((T - 1 - s) // wlen) * wlen, 0, k.shape[2] - 1)

        def take(a):
            return jnp.take(a, j, axis=2)

        return {
            "layers": {"k": take(k), "v": take(cache["layers"]["v"])},
            "len": cache["len"],
            "pos": cache["pos"],
        }

    def moe_prefill_capacity(self, rows: int) -> Optional[int]:
        """Full-sequence expert capacity for a ``rows``-row prompt (None
        for non-MoE archs) — what every chunk of that prompt must use."""
        if self.cfg.family != "moe":
            return None
        return L.moe_capacity(self.cfg, rows)

    def decode_step(
        self, params: Params, cache: Params, tokens: jax.Array
    ) -> Tuple[jax.Array, Params]:
        """One token step.  tokens [B, 1] -> (logits [B, 1, V], new cache)."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, cfg)
        b = tokens.shape[0]
        # decode rope positions: the positional counter (== len except VLM)
        pos0 = cache.get("pos", cache["len"])
        if jnp.ndim(pos0) == 1:  # per-slot pool cache: [B] counters
            pos = pos0.astype(jnp.int32)[:, None]
        else:
            pos = (pos0 + jnp.arange(1, dtype=jnp.int32))[None]
            pos = jnp.broadcast_to(pos, (b, 1))
        if cfg.mrope_sections:
            pos = jnp.stack([pos, pos, pos], axis=-1)

        def body(carry, xs):
            out, new_c, _, _ = self._block(
                xs["p"], carry, positions=pos, cache={**xs["c"], "len": cache["len"]},
                kv_valid_len=None,
            )
            return out, {"k": new_c["k"], "v": new_c["v"]}

        h, new_layer_caches = L.scan_blocks(body, x, {"p": params["blocks"], "c": cache["layers"]})
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = L.unembed(params["unembed"], h, cfg, params["embed"])
        new_cache = {
            "layers": {"k": new_layer_caches["k"], "v": new_layer_caches["v"]},
            "len": cache["len"] + 1,
            "pos": cache.get("pos", cache["len"]) + 1,
        }
        return logits, new_cache


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over positions with label >= 0 (f32 reductions)."""
    lg = logits.astype(jnp.float32)
    m = jnp.max(lg, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
    safe = jnp.maximum(labels, 0)
    picked = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
    nll = lse - picked
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
