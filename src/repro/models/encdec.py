"""Encoder-decoder backbone (seamless-m4t-large-v2).

The modality frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, T_src, frontend_dim]; a linear
projection maps them into the model.  Text decoder: token embeddings +
sinusoidal positions, causal self-attention + cross-attention, both through
the STAR softmax engine.  LayerNorm (pre-LN) as in the NLLB/seamless stack.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import ops
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import ParamSpec
from repro.models.transformer import _stack_specs, cross_entropy

Params = Dict[str, Any]


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg.validate()

    # -- specs ---------------------------------------------------------------

    def enc_block_spec(self) -> Params:
        cfg = self.cfg
        return {
            "ln1": L.spec_layernorm(cfg),
            "attn": L.spec_attention(cfg),
            "ln2": L.spec_layernorm(cfg),
            "mlp": L.spec_mlp(cfg),
        }

    def dec_block_spec(self) -> Params:
        cfg = self.cfg
        return {
            "ln1": L.spec_layernorm(cfg),
            "self_attn": L.spec_attention(cfg),
            "ln2": L.spec_layernorm(cfg),
            "cross_attn": L.spec_attention(cfg, cross=True),
            "ln3": L.spec_layernorm(cfg),
            "mlp": L.spec_mlp(cfg),
        }

    def param_specs(self) -> Params:
        cfg = self.cfg
        fd = cfg.frontend_dim or cfg.d_model
        return {
            "frontend_proj": {
                "kernel": ParamSpec((fd, cfg.d_model), (None, "embed"), L.pdtype(cfg), "fan_in")
            },
            "embed": L.spec_embedding(cfg),
            "enc_blocks": _stack_specs(self.enc_block_spec(), cfg.num_layers),
            "enc_norm": L.spec_layernorm(cfg),
            "dec_blocks": _stack_specs(self.dec_block_spec(), cfg.num_decoder_layers),
            "dec_norm": L.spec_layernorm(cfg),
            "unembed": L.spec_unembed(cfg),
        }

    # -- encoder ---------------------------------------------------------------

    def encode(self, params: Params, src_embeds: jax.Array) -> jax.Array:
        cfg = self.cfg
        dt = L.cdtype(cfg)
        x = jnp.einsum(
            "btf,fd->btd", src_embeds.astype(dt), params["frontend_proj"]["kernel"].astype(dt)
        )
        x = x + L.sinusoidal_positions(0, x.shape[1], cfg.d_model).astype(dt)[None]

        def body(h, bp):
            a, _, _ = L.attention_block(
                bp["attn"], L.layernorm(bp["ln1"], h, cfg.norm_eps), cfg,
                causal=False, use_rope=False,
            )
            h = h + L.attention_out(bp["attn"], a, cfg)
            h = h + L.mlp(bp["mlp"], L.layernorm(bp["ln2"], h, cfg.norm_eps), cfg)
            return h, None

        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = L.scan_blocks(body, x, params["enc_blocks"])
        return L.layernorm(params["enc_norm"], h, cfg.norm_eps)

    # -- decoder ---------------------------------------------------------------

    def _dec_block(self, bp, h, memory, cfg, cache=None, cache_len=None, pos0=0):
        self_cache = None
        if cache is not None:
            self_cache = {"k": cache["k"], "v": cache["v"], "len": cache_len}
        a, new_self, kv = L.attention_block(
            bp["self_attn"], L.layernorm(bp["ln1"], h, cfg.norm_eps), cfg,
            causal=True, cache=self_cache, use_rope=False,
        )
        h = h + L.attention_out(bp["self_attn"], a, cfg)
        c, _, cross_kv = L.attention_block(
            bp["cross_attn"], L.layernorm(bp["ln2"], h, cfg.norm_eps), cfg,
            xkv=memory, use_rope=False,
        )
        h = h + L.attention_out(bp["cross_attn"], c, cfg)
        h = h + L.mlp(bp["mlp"], L.layernorm(bp["ln3"], h, cfg.norm_eps), cfg)
        new_cache = None
        if cache is not None:
            new_cache = {"k": new_self["k"], "v": new_self["v"]}
        return h, new_cache, kv

    def decode_seq(
        self, params: Params, memory: jax.Array, tokens: jax.Array, pos0: int | jax.Array = 0
    ) -> jax.Array:
        """Full-sequence causal decoder -> hidden states."""
        cfg = self.cfg
        dt = L.cdtype(cfg)
        x = L.embed(params["embed"], tokens, cfg)
        x = x + L.sinusoidal_positions(pos0, tokens.shape[1], cfg.d_model).astype(dt)[None]

        def body(h, bp):
            h, _, _ = self._dec_block(bp, h, memory, cfg)
            return h, None

        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = L.scan_blocks(body, x, params["dec_blocks"])
        return L.layernorm(params["dec_norm"], h, cfg.norm_eps)

    # -- public API --------------------------------------------------------------

    def forward(self, params: Params, batch_or_tokens, **kw) -> jax.Array:
        """Training forward.  Accepts {'src_embeds', 'tokens'} or positional."""
        if isinstance(batch_or_tokens, dict):
            src = batch_or_tokens["src_embeds"]
            tokens = batch_or_tokens["tokens"]
        else:
            tokens = batch_or_tokens
            src = kw["src_embeds"]
        memory = self.encode(params, src)
        h = self.decode_seq(params, memory, tokens)
        return L.unembed(params["unembed"], h, self.cfg, params["embed"])

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        logits = self.forward(params, batch)
        return cross_entropy(logits, batch["labels"])

    # -- serving --------------------------------------------------------------

    def cache_spec(self, batch: int, max_len: int, src_len: int = 4096) -> Params:
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        hd = cfg.resolved_head_dim
        self_kv = (cfg.num_decoder_layers, batch, max_len, cfg.num_kv_heads, hd)
        cross_kv = (cfg.num_decoder_layers, batch, src_len, cfg.num_kv_heads, hd)
        axes = ("layers", "batch", "kv_seq", "kv_heads", None)
        return {
            "self": {
                "k": ParamSpec(self_kv, axes, dt, "zeros"),
                "v": ParamSpec(self_kv, axes, dt, "zeros"),
            },
            "cross": {
                "k": ParamSpec(cross_kv, axes, dt, "zeros"),
                "v": ParamSpec(cross_kv, axes, dt, "zeros"),
            },
            "len": ParamSpec((), (), jnp.int32, "zeros"),
        }

    def prefill(
        self, params: Params, tokens: jax.Array, max_len: int,
        *, src_embeds: jax.Array, **_,
    ) -> Tuple[jax.Array, Params]:
        """Encode source; run decoder prompt; prime self+cross caches."""
        cfg = self.cfg
        dt = L.cdtype(cfg)
        memory = self.encode(params, src_embeds)
        b, t = tokens.shape
        x = L.embed(params["embed"], tokens, cfg)
        x = x + L.sinusoidal_positions(0, t, cfg.d_model).astype(dt)[None]

        def body(h, bp):
            h, _, kv = self._dec_block(bp, h, memory, cfg)
            return h, {"k": kv[0], "v": kv[1]}

        if cfg.remat:
            body = jax.checkpoint(body)
        h, self_kvs = L.scan_blocks(body, x, params["dec_blocks"])
        h = L.layernorm(params["dec_norm"], h, cfg.norm_eps)
        logits = L.unembed(params["unembed"], h[:, -1:], cfg, params["embed"])

        # cross K/V: project memory through each decoder layer's cross proj
        def cross_body(_, bp):
            k = jnp.einsum("btd,dh->bth", memory, bp["cross_attn"]["wk"].astype(dt))
            v = jnp.einsum("btd,dh->bth", memory, bp["cross_attn"]["wv"].astype(dt))
            hd = cfg.resolved_head_dim
            k = k.reshape(b, memory.shape[1], cfg.num_kv_heads, hd)
            v = v.reshape(b, memory.shape[1], cfg.num_kv_heads, hd)
            return 0, {"k": k, "v": v}

        _, cross_kvs = L.scan_blocks(cross_body, 0, params["dec_blocks"])

        k_init, v_init = L.fit_window_cache(self_kvs["k"], self_kvs["v"], 2, max_len, t)
        return logits, {
            "self": {"k": k_init, "v": v_init},
            "cross": cross_kvs,
            "len": jnp.asarray(t, jnp.int32),
        }

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array):
        cfg = self.cfg
        dt = L.cdtype(cfg)
        b = tokens.shape[0]
        x = L.embed(params["embed"], tokens, cfg)
        x = x + L.sinusoidal_positions(cache["len"], 1, cfg.d_model).astype(dt)[None]

        def body(h, xs):
            bp, sc, cc = xs["p"], xs["s"], xs["x"]
            a, new_self, _ = L.attention_block(
                bp["self_attn"], L.layernorm(bp["ln1"], h, cfg.norm_eps), cfg,
                causal=True, cache={**sc, "len": cache["len"]}, use_rope=False,
            )
            h = h + L.attention_out(bp["self_attn"], a, cfg)
            # cross-attn against cached memory K/V
            hn = L.layernorm(bp["ln2"], h, cfg.norm_eps)
            q = jnp.einsum("btd,dh->bth", hn, bp["cross_attn"]["wq"].astype(dt))
            hd = cfg.resolved_head_dim
            q = q.reshape(b, 1, cfg.num_heads, hd)
            ctx = ops.attention(
                q, cc["k"], cc["v"], cfg.attention_spec,
                causal=False, sliding_window=None,
            )
            ctx = ctx.reshape(b, 1, -1)
            h = h + L.attention_out(bp["cross_attn"], ctx, cfg)
            h = h + L.mlp(bp["mlp"], L.layernorm(bp["ln3"], h, cfg.norm_eps), cfg)
            return h, {"k": new_self["k"], "v": new_self["v"]}

        h, new_self = L.scan_blocks(
            body, x, {"p": params["dec_blocks"], "s": cache["self"], "x": cache["cross"]}
        )
        h = L.layernorm(params["dec_norm"], h, cfg.norm_eps)
        logits = L.unembed(params["unembed"], h, cfg, params["embed"])
        return logits, {
            "self": new_self, "cross": cache["cross"], "len": cache["len"] + 1,
        }
