"""The training loop: checkpoint/restart, preemption, straggler watchdog.

Runs identically on 1 CPU device (tests/examples) and on a production mesh
(the launcher passes mesh + rules; params/opt-state get sharded, batches get
placed with batch sharding).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer
from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, make_batch
from repro.distributed.fault import FailureInjector, PreemptionGuard, StragglerWatchdog
from repro.distributed.sharding import (
    DEFAULT_RULES,
    Rules,
    param_shardings,
    use_mesh_rules,
)
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.train.state import init_state, state_specs
from repro.train.step import TrainConfig, make_train_step

PyTree = Any


@dataclasses.dataclass
class LoopConfig:
    num_steps: int = 20
    batch: int = 8
    seq_len: int = 64
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 10
    keep_ckpts: int = 3
    log_every: int = 5
    seed: int = 0
    straggler_threshold: float = 2.5


def run_train(
    model_cfg: ModelConfig,
    train_cfg: TrainConfig = TrainConfig(),
    loop_cfg: LoopConfig = LoopConfig(),
    *,
    mesh=None,
    rules: Optional[Rules] = None,
    data_cfg: DataConfig = DataConfig(),
    failure_injector: Optional[FailureInjector] = None,
    log_fn: Callable[[str], None] = print,
) -> Dict[str, Any]:
    """Train; auto-resume from loop_cfg.ckpt_dir if a checkpoint exists.

    Returns {"state": final state, "history": metrics, "stragglers": [...]}.
    """
    model = build_model(model_cfg)
    specs = model.param_specs()
    sspecs = state_specs(specs, train_cfg.adamw)
    rules = rules or DEFAULT_RULES

    step_fn = make_train_step(model, train_cfg)
    if mesh is not None:
        shardings = param_shardings(sspecs, rules, mesh)
        step_fn = jax.jit(step_fn, donate_argnums=(0,))
    else:
        shardings = None
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    # --- init or resume -----------------------------------------------------
    start_step = 0
    state = None
    if loop_cfg.ckpt_dir and checkpointer.latest_step(loop_cfg.ckpt_dir) is not None:
        template = jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype),
            sspecs,
            is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"),
        )
        state, start_step = checkpointer.restore(
            loop_cfg.ckpt_dir, template, shardings=shardings
        )
        log_fn(f"[loop] resumed from step {start_step}")
    if state is None:
        with use_mesh_rules(mesh, rules):
            state = init_state(specs, jax.random.PRNGKey(loop_cfg.seed), train_cfg.adamw)
        if shardings is not None:
            state = jax.device_put(state, shardings)

    watchdog = StragglerWatchdog(threshold=loop_cfg.straggler_threshold)
    history = []

    ctx = use_mesh_rules(mesh, rules)
    with ctx, PreemptionGuard() as guard:
        step = start_step
        while step < loop_cfg.num_steps:
            if failure_injector is not None:
                failure_injector.maybe_fail(step)
            batch_np = make_batch(
                model_cfg, batch=loop_cfg.batch, seq_len=loop_cfg.seq_len,
                step=step, data_cfg=data_cfg,
            )
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            metrics = jax.device_get(metrics)
            dt = time.perf_counter() - t0
            straggler = watchdog.observe(dt, step)
            step += 1
            history.append({"step": step, **{k: float(v) for k, v in metrics.items()}})
            if step % loop_cfg.log_every == 0 or step == loop_cfg.num_steps:
                log_fn(
                    f"[loop] step {step} loss {history[-1]['loss']:.4f} "
                    f"gnorm {history[-1]['grad_norm']:.3f} dt {dt*1e3:.0f}ms"
                    + (" STRAGGLER" if straggler else "")
                )
            want_ckpt = loop_cfg.ckpt_dir and (
                step % loop_cfg.ckpt_every == 0
                or step == loop_cfg.num_steps
                or guard.requested
            )
            if want_ckpt:
                checkpointer.save(loop_cfg.ckpt_dir, step, state)
                checkpointer.rotate(loop_cfg.ckpt_dir, loop_cfg.keep_ckpts)
            if guard.requested:
                log_fn(f"[loop] preemption requested; checkpointed at {step}")
                break

    return {
        "state": state,
        "history": history,
        "stragglers": watchdog.events,
        "final_step": step,
    }
