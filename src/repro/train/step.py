"""Train / eval step factories: grad accumulation, clipping, AdamW, sharded.

``make_train_step`` returns a pure function suitable both for direct jit on
one device and for pjit-with-shardings on the production mesh (the dry-run
lowers exactly this function).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, adamw_update, clip_by_global_norm
from repro.optim import schedule as schedule_lib

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | constant
    grad_clip: float = 1.0
    microbatches: int = 1
    adamw: AdamWConfig = AdamWConfig()


def make_train_step(model, train_cfg: TrainConfig) -> Callable:
    sched = {
        "cosine": schedule_lib.cosine_with_warmup,
        "constant": schedule_lib.constant,
    }[train_cfg.schedule]

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if train_cfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        mb = train_cfg.microbatches

        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb_batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, mb_batch)
            acc_loss, acc_grads = carry
            return (
                acc_loss + loss / mb,
                jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / mb, acc_grads, grads),
            ), None

        from repro.core.scan_ctl import scan_or_unroll

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = scan_or_unroll(body, (jnp.zeros((), jnp.float32), zero), micro)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        return loss, grads

    def train_step(state: PyTree, batch: Dict[str, jax.Array]):
        loss, grads = grads_of(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        step1 = state["step"] + 1
        lr = sched(
            step1, peak_lr=train_cfg.peak_lr,
            warmup=train_cfg.warmup_steps, total=train_cfg.total_steps,
        )
        new_params, new_opt = adamw_update(
            grads, state["opt"], state["params"],
            lr=lr, cfg=train_cfg.adamw, step=step1,
        )
        new_state = {"params": new_params, "opt": new_opt, "step": step1}
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(state: PyTree, batch: Dict[str, jax.Array]):
        return model.loss(state["params"], batch)

    return eval_step
