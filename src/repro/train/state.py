"""TrainState: params + optimizer moments + step, with spec/sharding views."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.param import ParamSpec, materialize, shape_tree
from repro.optim.adamw import AdamWConfig, init_opt_state, opt_state_specs

PyTree = Any


def state_specs(param_specs: PyTree, adamw: AdamWConfig = AdamWConfig()) -> PyTree:
    """ParamSpec tree for the full train state."""
    return {
        "params": param_specs,
        "opt": opt_state_specs(param_specs, adamw),
        "step": ParamSpec((), (), jnp.int32, "zeros"),
    }


def init_state(param_specs: PyTree, key: jax.Array,
               adamw: AdamWConfig = AdamWConfig()) -> PyTree:
    params = materialize(param_specs, key)
    return {
        "params": params,
        "opt": init_opt_state(params, adamw),
        "step": jnp.zeros((), jnp.int32),
    }


def state_shapes(param_specs: PyTree, adamw: AdamWConfig = AdamWConfig()) -> PyTree:
    return shape_tree(state_specs(param_specs, adamw))
