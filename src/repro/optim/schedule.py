"""LR schedules (pure functions of step)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)


def constant(step, *, peak_lr: float, **_):
    return jnp.full_like(step.astype(jnp.float32), peak_lr)
