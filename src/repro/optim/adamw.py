"""AdamW with fp32 moments (bf16-param-safe), built from scratch."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.param import ParamSpec

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # fp32 moments by default; "bfloat16" halves optimizer-state HBM (the
    # 405B-class memory lever — PaLM/T5X-style low-precision Adam)
    moments_dtype: str = "float32"


def opt_state_specs(param_specs: PyTree, cfg: AdamWConfig = AdamWConfig()) -> PyTree:
    """ParamSpec tree for the optimizer moments (same sharding as params)."""
    mdt = jnp.dtype(cfg.moments_dtype)

    def _moment(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.axes, mdt, "zeros")

    mk = lambda: jax.tree.map(_moment, param_specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return {"mu": mk(), "nu": mk()}


def init_opt_state(params: PyTree, cfg: AdamWConfig = AdamWConfig()) -> PyTree:
    mdt = jnp.dtype(cfg.moments_dtype)
    z = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
    return {"mu": z(), "nu": z()}


def adamw_update(
    grads: PyTree,
    opt_state: PyTree,
    params: PyTree,
    *,
    lr: jax.Array,
    cfg: AdamWConfig,
    step: jax.Array,  # 1-based
) -> tuple:
    """Returns (new_params, new_opt_state)."""
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * (g32 * g32)
        mhat = mu32 / c1
        vhat = nu32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mu32.astype(mdt), nu32.astype(mdt)

    flat_g, tree = jax.tree.flatten(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    newp = jax.tree.unflatten(tree, [o[0] for o in out])
    newmu = jax.tree.unflatten(tree, [o[1] for o in out])
    newnu = jax.tree.unflatten(tree, [o[2] for o in out])
    return newp, {"mu": newmu, "nu": newnu}


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: PyTree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm
