"""Exponential lookup tables — the LUT / VMM crossbar contents.

The paper preloads ``exp(z)`` for every representable ``z = x_i - x_max`` in
a LUT crossbar, and the *same values* in a VMM crossbar used to compute the
denominator ``sum_j count_j * exp(z_j)``.  Here both live as a single jnp
array; the two "crossbars" are the two ways it gets multiplied (row gather vs
count-vector matmul).
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.fixedpoint import FixedPointFormat


@functools.lru_cache(maxsize=64)
def _exp_lut_np(int_bits: int, frac_bits: int) -> np.ndarray:
    fmt = FixedPointFormat(int_bits, frac_bits)
    k = np.arange(fmt.num_levels, dtype=np.float64)
    return np.exp(-k / fmt.scale).astype(np.float32)


def exp_lut(fmt: FixedPointFormat, dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """``lut[k] = exp(-k / 2**frac_bits)`` — shape ``[num_levels]``.

    Entry 0 is exp(0)=1 (the max element); the last entry is
    ``exp(min_value)`` (the CAM's deepest row).
    """
    return jnp.asarray(_exp_lut_np(fmt.int_bits, fmt.frac_bits), dtype=dtype)


def exp_lut_int(fmt: FixedPointFormat, out_bits: int = 8) -> jax.Array:
    """Integer-mantissa LUT for the int8 P·V path (beyond-paper TPU trick).

    ``lut_int[k] = round(exp(-k/scale) * (2**(out_bits-1) - 1))`` — attention
    probabilities become int8 codes, enabling int8 MXU matmuls for P·V.
    """
    if not 2 <= out_bits <= 8:
        raise ValueError("out_bits must be in [2, 8]")
    top = (1 << (out_bits - 1)) - 1
    vals = _exp_lut_np(fmt.int_bits, fmt.frac_bits)
    return jnp.asarray(np.round(vals * top).astype(np.int8))


def int_lut_scale(out_bits: int = 8) -> float:
    """Dequantization scale for :func:`exp_lut_int` codes."""
    return 1.0 / float((1 << (out_bits - 1)) - 1)


def lookup_gather(k: jax.Array, lut: jax.Array) -> jax.Array:
    """VPU form: direct LUT gather (the digital shortcut)."""
    return jnp.take(lut, k.astype(jnp.int32), axis=0)


def lookup_onehot(k: jax.Array, lut: jax.Array) -> jax.Array:
    """MXU form — the faithful crossbar dataflow.

    The CAM match vector is one-hot over codebook rows; driving it through
    the LUT crossbar is exactly ``one_hot(k) @ lut``.  On TPU this puts the
    lookup on the systolic array (how XLA itself lowers small-table gathers).
    """
    onehot = jax.nn.one_hot(k.astype(jnp.int32), lut.shape[0], dtype=lut.dtype)
    return onehot @ lut


def histogram_counts(k: jax.Array, num_levels: int, axis: int = -1) -> jax.Array:
    """The counter: ``counts[..., j] = #{i : k[..., i] == j}`` along ``axis``.

    Implemented as a one-hot sum so it stays a dense MXU-friendly op under
    vmap/jit (no scatter).
    """
    onehot = jax.nn.one_hot(k.astype(jnp.int32), num_levels, dtype=jnp.float32)
    # one_hot appends the level dim at the end, shifting negative axes by one.
    return jnp.sum(onehot, axis=axis - 1 if axis < 0 else axis)


def histogram_dot(counts: jax.Array, lut: jax.Array) -> jax.Array:
    """The VMM crossbar: ``sum_j counts[..., j] * lut[j]``.

    One vector-matrix product replaces the length-d serial reduction — and
    dedups the exponentials (only ``num_levels`` distinct values exist).
    """
    return counts @ lut.astype(counts.dtype)
