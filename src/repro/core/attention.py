"""Attention with a pluggable softmax engine + the vector-grained pipeline.

Two execution paths, numerically cross-validated:

* :func:`attention` — materializes the score matrix (the classic layout the
  paper's *baseline* accelerators use: whole-operand granularity).
* :func:`blocked_attention` — the **vector-grained pipeline** (paper §II
  last ¶) as a ``lax.scan`` over KV blocks with online rescaling.  Softmax
  runs per score *vector block* interleaved with QKᵀ and P·V, never
  materializing the [Tq, Tk] matrix.  The Pallas kernel
  (``repro.kernels.flash_star``) implements the same schedule with explicit
  VMEM tiling; this is its lowering-independent reference.

STAR arithmetic stays closed under the online form: the running rescale
factor ``exp(m_old - m_new)`` has a nonpositive quantizable exponent, so it
is itself a LUT entry.

Shapes (TPU-native layout): q ``[B, Tq, Hq, D]``, k/v ``[B, Tk, Hkv, D]``,
``Hq % Hkv == 0`` (GQA; MQA when Hkv == 1).  Output ``[B, Tq, Hq, D]``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import lut as lut_lib
from repro.core.fixedpoint import (
    DEFAULT_FORMAT,
    GRID_SENTINEL,
    FixedPointFormat,
    grid_index,
    quantize_logits,
)
from repro.core.star_softmax import exact_softmax, star_softmax, star_softmax_ste
from repro.hwmodel.faults import FaultModel, is_null as _fault_is_null

NEG_INF = -1e30  # finite mask value: keeps CAM index math NaN-free


@dataclasses.dataclass(frozen=True)
class SoftmaxConfig:
    """Which softmax engine attention uses.

    kind: "exact" (FP oracle), "star" (quantized LUT), "star_ste"
    (quantized forward, straight-through backward — QAT).
    """

    kind: str = "star"
    fmt: FixedPointFormat = DEFAULT_FORMAT
    mode: str = "gather"  # star lowering: gather | onehot | histogram
    fault: Optional["FaultModel"] = None  # device non-idealities (§9)

    def __post_init__(self):
        if self.kind not in ("exact", "star", "star_ste"):
            raise ValueError(f"unknown softmax kind {self.kind!r}")

    @classmethod
    def from_spec(cls, spec) -> "SoftmaxConfig":
        """Build from a ``repro.ops.SoftmaxSpec`` (duck-typed: no import —
        core is a dispatch *target*, the specs live a layer above)."""
        if spec.kind == "exact":
            return cls(kind="exact")
        return cls(
            kind=spec.kind, fmt=spec.fmt, mode=spec.mode,
            fault=getattr(spec, "fault", None),
        )

    def apply(self, scores: jax.Array, where: Optional[jax.Array] = None) -> jax.Array:
        if self.kind == "exact":
            if where is not None:
                scores = jnp.where(where, scores, NEG_INF)
            return exact_softmax(scores, axis=-1)
        if self.kind == "star_ste":
            if where is not None:
                scores = jnp.where(where, scores, NEG_INF)
            # NEG_INF scores quantize to the deepest LUT row (prob ~ 0).
            return star_softmax_ste(scores, self.fmt, -1, self.mode, self.fault)
        return star_softmax(
            scores, self.fmt, axis=-1, mode=self.mode, where=where,
            fault=self.fault,
        )


EXACT_SOFTMAX = SoftmaxConfig(kind="exact")
STAR_SOFTMAX = SoftmaxConfig(kind="star")


def _build_mask(
    q_len: int,
    kv_len: int,
    *,
    causal: bool,
    sliding_window: Optional[int],
    q_offset: int | jax.Array = 0,
    kv_valid_len: Optional[jax.Array] = None,
) -> Optional[jax.Array]:
    """Boolean [*, Tq, Tk] mask; True = attend.

    ``q_offset``: absolute position of q row 0 (decode: cache length).
    ``kv_valid_len``: per-batch valid KV prefix (ragged batches), [B].
    """
    rows = jnp.arange(q_len)[:, None] + q_offset  # absolute q positions
    cols = jnp.arange(kv_len)[None, :]
    mask = None
    if causal:
        mask = cols <= rows
    if sliding_window is not None:
        w = cols > rows - sliding_window
        mask = w if mask is None else (mask & w)
    if kv_valid_len is not None:
        valid = cols[None] < kv_valid_len[:, None, None]  # [B, 1, Tk]
        mask = valid if mask is None else (mask[None] & valid)
    return mask


def _group_heads(q: jax.Array, hkv: int) -> jax.Array:
    """[B, T, Hq, D] -> [B, T, Hkv, G, D]."""
    b, t, hq, d = q.shape
    assert hq % hkv == 0, f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}"
    return q.reshape(b, t, hkv, hq // hkv, d)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    softmax: SoftmaxConfig = STAR_SOFTMAX,
    causal: bool = False,
    sliding_window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    kv_valid_len: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Whole-operand attention (scores materialized)."""
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    scale = (d ** -0.5) if scale is None else scale

    qg = _group_heads(q, hkv)  # [B, Tq, Hkv, G, D]
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale  # [B, Hkv, G, Tq, Tk]

    mask = _build_mask(
        tq, tk, causal=causal, sliding_window=sliding_window,
        q_offset=q_offset, kv_valid_len=kv_valid_len,
    )
    where = None
    if mask is not None:
        # broadcast mask to [B, 1, 1, Tq, Tk]
        where = mask[:, None, None] if mask.ndim == 3 else mask[None, None, None]
    probs = softmax.apply(scores, where=where)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(probs.dtype))
    return out.reshape(b, tq, hq, d).astype(q.dtype)


def blocked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    softmax: SoftmaxConfig = STAR_SOFTMAX,
    causal: bool = False,
    sliding_window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    kv_valid_len: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    block_size: int = 512,
    rescale: str = "float",
) -> jax.Array:
    """Vector-grained pipeline: online blocked attention (lax.scan over KV).

    Per KV block: QKᵀ → STAR (or exact) softmax numerators → P·V, with
    running (max, denominator, accumulator) carried across blocks.

    ``rescale``: how the running factor ``exp(m_old - m_new)`` is computed
    under STAR arithmetic — ``"lut"`` keeps it a codebook entry (fully
    in-engine, compounds quantization error across blocks), ``"float"``
    computes the one scalar per row-block in FP (default; matches the
    paper's two-pass global-max semantics much more closely since the
    paper finds the global max *before* any LUT lookup).
    """
    if not _fault_is_null(softmax.fault):
        raise ValueError(
            "blocked_attention cannot inject cell faults: the online "
            "rescale identity lut[a] * lut[b] == lut[a + b] does not hold "
            "for a faulty LUT, so the pipeline would not model any "
            "physical engine.  Use the whole-operand attention() (the "
            "dispatch layer routes faulty specs there automatically)."
        )
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    scale = (d ** -0.5) if scale is None else scale
    star = softmax.kind in ("star", "star_ste")
    fmt = softmax.fmt
    table = lut_lib.exp_lut(fmt, dtype=jnp.float32) if star else None

    nblk = -(-tk // block_size)
    pad = nblk * block_size - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblk, block_size, hkv, d)
    vb = v.reshape(b, nblk, block_size, hkv, d)

    qg = _group_heads(q, hkv).astype(jnp.float32)  # [B, Tq, Hkv, G, D]
    rows = jnp.arange(tq)[:, None] + q_offset  # [Tq, 1]

    def body(carry, blk):
        m, s, o = carry
        kblk, vblk, idx = blk
        cols = idx * block_size + jnp.arange(block_size)[None, :]  # [1, Bk]
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kblk.astype(jnp.float32)) * scale
        mask = jnp.ones((tq, block_size), dtype=bool)
        if causal:
            mask &= cols <= rows
        if sliding_window is not None:
            mask &= cols > rows - sliding_window
        mask &= cols < tk  # padding block tail
        maskb = jnp.broadcast_to(mask[None, None, None], scores.shape)
        if kv_valid_len is not None:
            valid = cols[0] < kv_valid_len[:, None]  # [B, Bk]
            maskb = maskb & valid[:, None, None, None, :]

        if star:
            # Integer-grid online form: exactly equal to the two-pass STAR
            # softmax (grid subtraction exact; lut[a]*lut[b] = lut[a+b]).
            jgrid = jnp.where(maskb, quantize_logits(scores, fmt), GRID_SENTINEL)
            m_blk = jnp.max(jgrid, axis=-1)
            m_new = jnp.maximum(m, m_blk)
            shift = jnp.clip(m_new - m, 0, fmt.num_levels - 1)  # int >= 0
            r = lut_lib.lookup_gather(shift, table)
            # carry started at sentinel: force r so that 0-carry stays 0.
            p = lut_lib.lookup_gather(grid_index(jgrid, m_new[..., None], fmt), table)
            p = jnp.where(maskb, p, 0.0)
        else:
            scores = jnp.where(maskb, scores, NEG_INF)
            m_blk = jnp.max(scores, axis=-1)
            m_new = jnp.maximum(m, m_blk)
            r = jnp.exp(jnp.minimum(m - m_new, 0.0))
            p = jnp.exp(scores - m_new[..., None])
            p = jnp.where(maskb, p, 0.0)
        s_new = s * r + jnp.sum(p, axis=-1)
        o_new = o * r[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
        return (m_new, s_new, o_new), None

    ghq = hq // hkv
    if star:
        m0 = jnp.full((b, hkv, ghq, tq), GRID_SENTINEL, dtype=jnp.int32)
    else:
        m0 = jnp.full((b, hkv, ghq, tq), NEG_INF, dtype=jnp.float32)
    s0 = jnp.zeros((b, hkv, ghq, tq), dtype=jnp.float32)
    o0 = jnp.zeros((b, hkv, ghq, tq, d), dtype=jnp.float32)
    from repro.core.scan_ctl import scan_or_unroll

    (m, s, o), _ = scan_or_unroll(
        body,
        (m0, s0, o0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nblk)),
    )
    s = jnp.where(s <= 0.0, 1.0, s)
    out = o / s[..., None]  # the divider
    out = jnp.moveaxis(out, 3, 1)  # [B, Tq, Hkv, G, D]
    return out.reshape(b, tq, hq, d).astype(q.dtype)
