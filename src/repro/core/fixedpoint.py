"""Fixed-point formats for the STAR softmax codebook.

The paper stores all possible values of ``x_i - x_max`` (always <= 0, so the
sign bit is dropped) in a CAM crossbar at a dataset-dependent fixed-point
precision:

    CNEWS : 8 bits = 6 integer + 2 fractional
    MRPC  : 9 bits = 6 integer + 3 fractional
    CoLA  : 7 bits = 5 integer + 2 fractional

On TPU the CAM "match" becomes quantize-to-index: a nonpositive value ``z``
maps to the unsigned index ``k = round(-z * 2**frac_bits)`` clipped to the
codebook, and the CAM/LUT pair becomes ``lut[k]`` (gather) or
``one_hot(k) @ lut`` (MXU form). ``dequantize`` recovers the codebook value
``-k / 2**frac_bits``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FixedPointFormat:
    """Unsigned fixed-point format for nonpositive inputs (sign dropped).

    Represents the codebook ``{-k / 2**frac_bits : k = 0 .. 2**bits - 1}``.
    """

    int_bits: int
    frac_bits: int

    def __post_init__(self) -> None:
        if self.int_bits < 0 or self.frac_bits < 0:
            raise ValueError("bit counts must be nonnegative")
        if self.total_bits <= 0:
            raise ValueError("format must have at least one bit")
        if self.total_bits > 16:
            raise ValueError(
                "codebooks beyond 16 bits defeat the purpose of STAR "
                f"(got {self.total_bits} bits)"
            )

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits

    @property
    def num_levels(self) -> int:
        return 1 << self.total_bits

    @property
    def scale(self) -> float:
        """Levels per unit: index k represents -k / scale."""
        return float(1 << self.frac_bits)

    @property
    def min_value(self) -> float:
        """Most negative representable value."""
        return -(self.num_levels - 1) / self.scale

    @property
    def resolution(self) -> float:
        return 1.0 / self.scale

    def short_name(self) -> str:
        return f"u{self.total_bits}({self.int_bits}i.{self.frac_bits}f)"


# Paper's per-dataset formats (Section II).
FORMAT_CNEWS = FixedPointFormat(int_bits=6, frac_bits=2)  # 8 bits
FORMAT_MRPC = FixedPointFormat(int_bits=6, frac_bits=3)  # 9 bits
FORMAT_COLA = FixedPointFormat(int_bits=5, frac_bits=2)  # 7 bits

# Default format used by the framework when none is configured: the paper's
# 8-bit CNEWS format (the one used for Table I / Fig. 3 comparisons).
DEFAULT_FORMAT = FORMAT_CNEWS


def quantize_index(z: jax.Array, fmt: FixedPointFormat) -> jax.Array:
    """Map nonpositive values ``z`` to unsigned codebook indices.

    ``k = clip(round(-z * scale), 0, num_levels - 1)``.  Positive inputs
    (which cannot occur for ``x - max(x)`` but may for user input) clamp to
    index 0; values below ``min_value`` clamp to the last level — exactly the
    CAM behaviour (out-of-range entries match the closest stored row).

    NaNs map to the last level (probability ~ e^min_value ~ 0) so a single
    bad logit cannot poison the row the way ``exp(nan)`` would.
    """
    scaled = jnp.round(-z * fmt.scale)
    scaled = jnp.where(jnp.isnan(scaled), float(fmt.num_levels - 1), scaled)
    scaled = jnp.clip(scaled, 0.0, float(fmt.num_levels - 1))
    dtype = jnp.uint8 if fmt.num_levels <= 256 else jnp.uint16
    return scaled.astype(dtype)


def quantize_logits(x: jax.Array, fmt: FixedPointFormat) -> jax.Array:
    """Snap raw logits onto the signed fixed-point grid: ``round(x * scale)``.

    This is the CAM-at-input view: the paper's CAM matches each ``x_i``
    against stored codebook rows, i.e. inputs are quantized to the grid
    *before* the subtraction.  Working on the integer grid makes the online
    (blocked) softmax **exactly** equal to the two-pass one, because grid
    subtraction is exact and ``lut[a] * lut[b] == lut[a + b]`` in exact
    arithmetic.  NaNs map to a very deep sentinel (probability ~ 0).
    """
    j = jnp.round(x.astype(jnp.float32) * fmt.scale)
    j = jnp.where(jnp.isnan(j), jnp.float32(GRID_SENTINEL), j)
    j = jnp.clip(j, float(GRID_SENTINEL), float(-GRID_SENTINEL))
    return j.astype(jnp.int32)


# Sentinel for "masked / -inf" logits on the integer grid.  Deep enough that
# (max - sentinel) always clips to the last LUT level, small enough that
# int32 arithmetic never overflows.
GRID_SENTINEL = -(1 << 24)


def grid_index(j: jax.Array, m: jax.Array, fmt: FixedPointFormat) -> jax.Array:
    """Codebook index from grid logits ``j`` and grid row-max ``m``.

    ``k = clip(m - j, 0, num_levels - 1)`` — the integer-domain CAM match.
    """
    return jnp.clip(m - j, 0, fmt.num_levels - 1)


def dequantize(k: jax.Array, fmt: FixedPointFormat) -> jax.Array:
    """Codebook value for index ``k``: ``-k / scale`` (float32)."""
    return -(k.astype(jnp.float32)) / fmt.scale


def quantize_value(z: jax.Array, fmt: FixedPointFormat) -> jax.Array:
    """Round-trip ``z`` through the codebook (quantize then dequantize)."""
    return dequantize(quantize_index(z, fmt), fmt)


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantize_value_ste(z: jax.Array, fmt: FixedPointFormat) -> jax.Array:
    """Straight-through-estimator round-trip for quantization-aware training.

    Forward: codebook round-trip.  Backward: identity inside the clip range,
    zero outside (standard STE with saturation masking).
    """
    return quantize_value(z, fmt)


def _ste_fwd(z, fmt):
    return quantize_value(z, fmt), z


def _ste_bwd(fmt, z, g):
    in_range = (z <= 0.0) & (z >= fmt.min_value)
    return (jnp.where(in_range, g, 0.0).astype(g.dtype),)


quantize_value_ste.defvjp(_ste_fwd, _ste_bwd)
