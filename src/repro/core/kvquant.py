"""Quantized KV block storage: per-block, per-head scales (DESIGN.md §13).

STAR's core trade — attention tolerates reduced-fidelity operands — applied
to KV *storage*: cache pages hold low-bit codes (``int8`` or ``fp8_e4m3``)
and a float32 scale per (block, kv_head) restores them on the fly.  The
scale granularity matches the page-pool layout (``repro.serve.paged``): one
scale row per block id, so scales share the block's lifecycle exactly —
allocate / free / CoW-copy / prefix-share all move the scale row with its
block, and any reader that pairs a block's codes with that block's scale is
self-consistent by construction.

Symmetric absmax quantization:

* ``int8``      — ``scale = absmax / 127``, codes round-to-nearest int8;
* ``fp8_e4m3``  — ``scale = absmax / 448``, codes cast to
  ``float8_e4m3fn`` after clipping to ±448 (values past ±448 cast to NaN,
  so the clip is load-bearing, not cosmetic);
* ``fp32``      — the identity layout: no codes, no scale pages.

Roundtrip error per element is bounded by ``scale / 2`` for int8 (the
rounding grid) and by half the widest e4m3 ulp (``16 * scale``) for fp8 —
the property suite in ``tests/test_kv_quant.py`` pins both bounds.

Decode writes land one row at a time, so a block's scale is *stamped* when
its first row is written (fresh blocks only — ring wrap-around keeps the
existing stamp, because earlier laps' rows still decode through it) and
later rows reuse the stamp with clipping.  A clipped row loses fidelity,
never soundness: write and read always use the same scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

KV_DTYPES = ("fp32", "int8", "fp8_e4m3")

# Largest representable magnitude of each code grid: int8 keeps the
# symmetric [-127, 127] range (no -128: absmax maps to ±qmax exactly);
# e4m3fn saturates at 448 and casts anything beyond to NaN.
_QMAX = {"int8": 127.0, "fp8_e4m3": 448.0}

# Scale floor: an all-zero block would stamp scale 0 and turn the decode
# divide into 0/0.  The floor keeps the divide finite; zero rows still
# encode and decode to exact zeros.
_EPS = 1e-8

_STORAGE = {
    "int8": jnp.int8,
    "fp8_e4m3": jnp.float8_e4m3fn,
}


def validate_kv_dtype(kv_dtype: str) -> str:
    if kv_dtype not in KV_DTYPES:
        raise ValueError(
            f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}"
        )
    return kv_dtype


def storage_dtype(kv_dtype: str) -> jnp.dtype:
    """The cache-leaf dtype codes are stored in (fp32 has no code grid)."""
    validate_kv_dtype(kv_dtype)
    if kv_dtype == "fp32":
        raise ValueError("fp32 KV pages store values directly, not codes")
    return jnp.dtype(_STORAGE[kv_dtype])


def dtype_of(dtype) -> str:
    """Map a cache-leaf dtype back to its ``kv_dtype`` name.

    Any float wider than a code grid reads as ``"fp32"`` — the identity
    layout — so callers can derive the quantization mode from the pool
    leaves alone (the cache pytree is the source of truth, not a flag).
    """
    dt = jnp.dtype(dtype)
    for name, stored in _STORAGE.items():
        if dt == jnp.dtype(stored):
            return name
    return "fp32"


def qmax(kv_dtype: str) -> float:
    validate_kv_dtype(kv_dtype)
    return _QMAX[kv_dtype]


def scale_of(absmax: jax.Array, kv_dtype: str) -> jax.Array:
    """Symmetric scale for a given absolute maximum (floored, float32)."""
    return jnp.maximum(absmax.astype(jnp.float32), _EPS) / _QMAX[kv_dtype]


def encode(x: jax.Array, scale: jax.Array, kv_dtype: str) -> jax.Array:
    """Quantize ``x`` onto the code grid using ``scale`` (broadcast).

    Values outside the scale's range clip to the grid edge — the stale-
    stamp decode path relies on this (fidelity loss, never NaN/overflow).
    """
    y = x.astype(jnp.float32) / scale
    if kv_dtype == "int8":
        return jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)
    if kv_dtype == "fp8_e4m3":
        # Round onto the e4m3 grid in float32 *before* the cast: neither
        # XLA-CPU nor ml_dtypes round-to-nearest on this conversion (both
        # can be a full ulp off), which would double the roundtrip bound
        # the property suite pins.  Casting an exactly-representable value
        # is exact, so compute that value ourselves: ulp = 2^(e-3) with
        # e = floor(log2|y|) clipped to the normal/subnormal exponent
        # range, round-to-nearest-even on that grid, then saturate at
        # ±448 (|y| > 448 casts to NaN in e4m3fn, so the clip is
        # load-bearing).
        mag = jnp.maximum(jnp.abs(y), 2.0**-9)
        exp = jnp.clip(jnp.floor(jnp.log2(mag)), -6.0, 8.0)
        ulp = jnp.exp2(exp - 3.0)
        q = jnp.round(y / ulp) * ulp
        return jnp.clip(q, -448.0, 448.0).astype(jnp.float8_e4m3fn)
    raise ValueError(f"no code grid for kv_dtype {kv_dtype!r}")


def decode(codes: jax.Array, scale: jax.Array) -> jax.Array:
    """Restore codes to float32 — the single dequant expression every
    reader (kernel, gather oracle, prefix-cache staging) must share so the
    operands they build are bit-identical."""
    return codes.astype(jnp.float32) * scale


def quantize_blocks(x: jax.Array, kv_dtype: str):
    """Quantize whole blocks: ``[..., bs, H, D] -> (codes, scale[..., H])``.

    One scale per (block, head): the absmax reduces over the block's rows
    and the head dim, leaving the head axis — the granularity the paged
    decode kernel reads back as a per-grid-step scalar.
    """
    validate_kv_dtype(kv_dtype)
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-3, -1))
    scale = scale_of(absmax, kv_dtype)
    codes = encode(x, scale[..., None, :, None], kv_dtype)
    return codes, scale


def row_scale(x: jax.Array, kv_dtype: str) -> jax.Array:
    """Scale a single token row ``[..., H, D]`` would stamp: ``[..., H]``."""
    return scale_of(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1), kv_dtype)
