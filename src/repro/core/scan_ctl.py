"""Global scan-unroll context for dry-run cost probes.

XLA's ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count, so FLOPs/bytes/collectives of scanned programs are undercounted.
The dry-run extracts exact costs from *unrolled* depth-1/2 probe compiles
(and extrapolates), then takes memory from the real scanned compile.  This
context flips every structural scan (layer stacks, SSD chunk scans, blocked
attention) to its unrolled form without touching model code paths.
"""

from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def unroll_scans_enabled() -> bool:
    return getattr(_state, "on", False)


@contextlib.contextmanager
def unroll_scans(on: bool = True):
    prev = getattr(_state, "on", False)
    _state.on = on
    try:
        yield
    finally:
        _state.on = prev


def scan_or_unroll(body, carry, xs, length=None):
    """lax.scan unless the unroll context is active."""
    import jax
    import jax.numpy as jnp

    if not unroll_scans_enabled():
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys
