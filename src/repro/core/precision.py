"""Per-dataset precision policy (paper Section II precision analysis).

The paper profiles the dynamic range of attention logits per dataset on
BERT-base and picks the smallest fixed-point format preserving accuracy.
``policy_for`` exposes those formats; ``calibrate_format`` re-derives a
format from observed logits (the same procedure, runnable on any model).
"""

from __future__ import annotations

import math
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import (
    FORMAT_CNEWS,
    FORMAT_COLA,
    FORMAT_MRPC,
    DEFAULT_FORMAT,
    FixedPointFormat,
)

_PAPER_POLICIES: Dict[str, FixedPointFormat] = {
    "cnews": FORMAT_CNEWS,
    "mrpc": FORMAT_MRPC,
    "cola": FORMAT_COLA,
}


def policy_for(dataset: str) -> FixedPointFormat:
    """Paper's calibrated format for a dataset; DEFAULT_FORMAT otherwise."""
    return _PAPER_POLICIES.get(dataset.lower(), DEFAULT_FORMAT)


def calibrate_format(
    z_samples: np.ndarray | jnp.ndarray,
    *,
    max_frac_bits: int = 4,
    target_max_abs_err: float = 2e-2,
    coverage: float = 0.9999,
) -> FixedPointFormat:
    """Derive (int_bits, frac_bits) from observed ``x - max`` samples.

    int_bits: cover the ``coverage`` quantile of |z| (the CAM depth).
    frac_bits: smallest count whose softmax output error bound
    ``e^{r/2} - 1 <= target_max_abs_err`` (r = resolution) holds, capped at
    ``max_frac_bits``.
    """
    z = np.asarray(z_samples, dtype=np.float64).ravel()
    z = z[np.isfinite(z)]
    if z.size == 0:
        return DEFAULT_FORMAT
    depth = float(np.quantile(np.abs(z), coverage))
    int_bits = max(1, int(math.ceil(math.log2(max(depth, 1.0) + 1.0))))
    frac_bits = max_frac_bits
    for fb in range(0, max_frac_bits + 1):
        r = 2.0 ** (-fb)
        if math.exp(r / 2.0) - 1.0 <= target_max_abs_err:
            frac_bits = fb
            break
    return FixedPointFormat(int_bits=int_bits, frac_bits=frac_bits)
