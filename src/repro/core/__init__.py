# The paper's primary contribution: the STAR softmax engine and the
# vector-grained attention pipeline, as composable JAX modules.
from repro.core.fixedpoint import (  # noqa: F401
    DEFAULT_FORMAT,
    FORMAT_CNEWS,
    FORMAT_COLA,
    FORMAT_MRPC,
    FixedPointFormat,
    dequantize,
    quantize_index,
    quantize_value,
    quantize_value_ste,
)
from repro.core.lut import (  # noqa: F401
    exp_lut,
    exp_lut_int,
    histogram_counts,
    histogram_dot,
    int_lut_scale,
    lookup_gather,
    lookup_onehot,
)
from repro.core.star_softmax import (  # noqa: F401
    exact_softmax,
    quantization_error,
    star_softmax,
    star_softmax_ste,
)
from repro.core.attention import (  # noqa: F401
    EXACT_SOFTMAX,
    STAR_SOFTMAX,
    SoftmaxConfig,
    attention,
    blocked_attention,
)
from repro.core.precision import calibrate_format, policy_for  # noqa: F401
