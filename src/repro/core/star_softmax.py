"""STAR softmax — the paper's softmax engine as a JAX primitive.

Pipeline (paper Section II, adapted per DESIGN.md §2):

  1. CAM max search        ->  row max reduction              (VPU)
  2. SUB crossbar          ->  z = x - max                    (VPU)
  3. CAM match             ->  k = quantize_index(z, fmt)     (VPU)
  4. LUT crossbar          ->  num = lut[k]  (gather | one-hot MXU)
  5. counter + VMM         ->  den = histogram(k) @ lut       (MXU)
  6. divider               ->  out = num / den                (VPU)

Three execution ``mode``s, numerically equivalent up to float summation
order:

  * ``"gather"``    — steps 4-5 by direct gather + sum (digital shortcut,
                      fastest on VPU for small rows).
  * ``"onehot"``    — step 4 via ``one_hot(k) @ lut`` (the faithful crossbar
                      dataflow; MXU).
  * ``"histogram"`` — step 5 via the counter + VMM trick: the denominator is
                      ``counts @ lut``; numerators still come from the LUT.
                      This is the paper's headline dataflow: the length-d
                      reduction collapses to a ``num_levels``-length VMM.

Training: ``star_softmax_ste`` keeps the quantized forward and routes
gradients through the exact softmax vjp evaluated at the *quantized*
probabilities (quantization-aware training).

Fault injection (DESIGN.md §9): an optional :class:`FaultModel` perturbs
the physical arrays each stage reads — the CAM match (broken rows remap to
the nearest working row), the numerator LUT, the denominator VMM crossbar
(an independent realization of the same contents), and the shared ADC
(denominator gain).  ``gather``/``onehot`` modes sum the faulty numerators
digitally, so only the LUT/CAM sites apply there; ``histogram`` mode runs
the denominator through the VMM + ADC sites too — under faults the three
modes are *deliberately* no longer equivalent, because the hardware paths
they model differ.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import lut as lut_lib
from repro.hwmodel import faults as faults_lib
from repro.hwmodel.faults import FaultModel
from repro.core.fixedpoint import (
    DEFAULT_FORMAT,
    GRID_SENTINEL,
    FixedPointFormat,
    grid_index,
    quantize_logits,
)

Modes = ("gather", "onehot", "histogram")


def exact_softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    """The FP oracle (numerically-stable softmax)."""
    return jax.nn.softmax(x, axis=axis)


def _move_axis_last(x: jax.Array, axis: int):
    axis = axis % x.ndim
    if axis == x.ndim - 1:
        return x, None
    return jnp.moveaxis(x, axis, -1), axis


def star_softmax(
    x: jax.Array,
    fmt: FixedPointFormat = DEFAULT_FORMAT,
    *,
    axis: int = -1,
    mode: str = "histogram",
    where: Optional[jax.Array] = None,
    dtype: Optional[jnp.dtype] = None,
    fault: Optional[FaultModel] = None,
) -> jax.Array:
    """Quantized LUT softmax along ``axis``.

    ``where`` masks entries out of the softmax (masked entries get
    probability 0 and do not enter the denominator) — needed for attention
    masking, where the paper's engine simply never streams masked scores.

    ``fault`` injects the seeded device non-idealities of DESIGN.md §9
    into the CAM/LUT/VMM/ADC stages (``None`` = ideal device).
    """
    if mode not in Modes:
        raise ValueError(f"mode must be one of {Modes}, got {mode!r}")
    out_dtype = dtype or (x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32)
    faulty = not faults_lib.is_null(fault)

    xf = x.astype(jnp.float32)
    moved, orig_axis = _move_axis_last(xf, axis)
    wmask = None
    if where is not None:
        wmask = jnp.broadcast_to(where, x.shape)
        wmask, _ = _move_axis_last(wmask, axis)

    # CAM-at-input quantization: snap logits onto the signed integer grid,
    # then max search and subtraction are exact integer ops (DESIGN.md §2).
    j = quantize_logits(moved, fmt)
    if wmask is not None:
        j = jnp.where(wmask, j, GRID_SENTINEL)
    m = jnp.max(j, axis=-1, keepdims=True)  # CAM max search (integer)
    k = grid_index(j, m, fmt)  # SUB crossbar + CAM match

    if faulty:
        remap = faults_lib.cam_remap(fmt, fault)
        if remap is not None:
            # broken CAM rows match the nearest working codebook row
            k = lut_lib.lookup_gather(k, remap)
        table = faults_lib.faulty_exp_lut(fmt, fault, tag="softmax/lut")
    else:
        table = lut_lib.exp_lut(fmt, dtype=jnp.float32)
    if mode == "onehot":
        num = lut_lib.lookup_onehot(k, table)
    else:
        num = lut_lib.lookup_gather(k, table)

    if where is not None:
        num = jnp.where(wmask, num, 0.0)

    if mode == "histogram":
        if where is None:
            counts = lut_lib.histogram_counts(k, fmt.num_levels, axis=-1)
        else:
            # Masked entries must not be counted: weight the one-hot rows.
            counts = _weighted_histogram(k, wmask, fmt.num_levels)
        # the denominator VMM crossbar holds an independent copy of the
        # LUT contents — its own fault realization and ADC
        vmm_table = (
            faults_lib.faulty_exp_lut(fmt, fault, tag="softmax/vmm")
            if faulty
            else table
        )
        den = lut_lib.histogram_dot(counts, vmm_table)[..., None]
        if faulty:
            gain = faults_lib.adc_gain(fault)
            if gain is not None:
                den = den * gain
    else:
        # gather/onehot sum the numerators digitally: LUT faults propagate,
        # no separate VMM/ADC site exists on this path
        den = jnp.sum(num, axis=-1, keepdims=True)

    den = jnp.where(den <= 0.0, 1.0, den)  # fully-masked rows -> zeros
    out = num / den
    if orig_axis is not None:
        out = jnp.moveaxis(out, -1, orig_axis)
    return out.astype(out_dtype)


def _weighted_histogram(k: jax.Array, weight_mask: jax.Array, num_levels: int) -> jax.Array:
    onehot = jax.nn.one_hot(k.astype(jnp.int32), num_levels, dtype=jnp.float32)
    onehot = onehot * weight_mask.astype(jnp.float32)[..., None]
    return jnp.sum(onehot, axis=-2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def star_softmax_ste(
    x: jax.Array,
    fmt: FixedPointFormat = DEFAULT_FORMAT,
    axis: int = -1,
    mode: str = "histogram",
    fault: Optional[FaultModel] = None,
) -> jax.Array:
    """STAR softmax with a straight-through backward.

    Backward uses the exact softmax vjp evaluated at the quantized forward
    probabilities: ``dx = p * (g - sum(g * p))``.  This is the standard QAT
    treatment — the quantizer is transparent to the gradient, the softmax
    geometry is kept.  ``fault`` (hashable, nondiff) perturbs the forward
    only — fault-aware training sees the degraded probabilities but trains
    through the clean geometry.
    """
    return star_softmax(x, fmt, axis=axis, mode=mode, fault=fault)


def _ste_fwd(x, fmt, axis, mode, fault):
    p = star_softmax(x, fmt, axis=axis, mode=mode, fault=fault)
    return p, p


def _ste_bwd(fmt, axis, mode, fault, p, g):
    inner = jnp.sum(g * p, axis=axis, keepdims=True)
    return ((p * (g - inner)).astype(g.dtype),)


star_softmax_ste.defvjp(_ste_fwd, _ste_bwd)


def quantization_error(
    x: jax.Array, fmt: FixedPointFormat, *, axis: int = -1, mode: str = "histogram"
) -> jax.Array:
    """Max |star_softmax - exact_softmax| per row (benchmark helper)."""
    err = jnp.abs(
        star_softmax(x, fmt, axis=axis, mode=mode) - exact_softmax(x, axis=axis)
    )
    return jnp.max(err, axis=axis)
