"""llama3-405b [arXiv:2407.21783; unverified] — GQA, 128k vocab.
126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        d_ff=53248,
        vocab_size=128256,
        rope_theta=500000.0,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        seq_parallel_activations=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=256,
        attn_block_size=32,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
