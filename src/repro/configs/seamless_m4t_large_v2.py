"""seamless-m4t-large-v2 [arXiv:2308.11596; hf] — enc-dec, multimodal.
24L(enc) + 24L(dec) d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
Audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="encdec",
        num_layers=24,
        num_decoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        mlp_type="gelu",
        frontend_dim=1024,
        param_dtype="float32",
        compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke",
        family="encdec",
        num_layers=2,
        num_decoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        mlp_type="gelu",
        frontend_dim=32,
        attn_block_size=32,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
