"""mixtral-8x22b [arXiv:2401.04088; hf]
56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2, SWA.
TP-MoE (8 experts < 16-way axis: expert FFNs column-parallel).  The sliding
window makes this a long_500k-eligible arch (window-capped KV cache)."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        num_experts=8,
        top_k=2,
        moe_style="tp",
        sliding_window=4096,
        rope_theta=1000000.0,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        seq_parallel_activations=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        num_experts=4,
        top_k=2,
        moe_style="tp",
        sliding_window=16,
        attn_block_size=32,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
