"""mamba2-130m [arXiv:2405.21060; unverified] — SSD (state-space duality).
24L d_model=768 (attn-free) vocab=50280, ssm_state=128.
d_inner = 1536, headdim 64 -> 24 SSD heads.  The paper's softmax engine is
inapplicable to the mixer (no softmax) — see DESIGN.md §5."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        num_layers=24,
        d_model=768,
        num_heads=24,
        num_kv_heads=24,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_conv=4,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_chunk=128,
        param_dtype="float32",
        compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=256,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        ssm_headdim=32,
        ssm_chunk=16,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
