"""granite-8b [arXiv:2405.04324; hf] — llama-arch code model.
36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.

Carries its op contract as ``repro.ops`` specs (the canonical form): the
online-blocked XLA attention pipeline around the STAR softmax engine.
"""

from repro.configs.base import ModelConfig
from repro.ops import AttentionSpec, SoftmaxSpec

STAR_GATHER = SoftmaxSpec(kind="star", mode="gather")


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=49152,
        rope_theta=10000.0,
        attention=AttentionSpec(impl="xla", softmax=STAR_GATHER, block_kv=512),
        param_dtype="float32",
        compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        attention=AttentionSpec(
            impl="xla", softmax=STAR_GATHER, block_q=32, block_k=32, block_kv=32
        ),
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
