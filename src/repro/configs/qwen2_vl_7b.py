"""qwen2-vl-7b [arXiv:2409.12191; hf] — M-RoPE, dynamic resolution.
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
Vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (ViT output dim 1280); M-RoPE sections
(16, 24, 24) over the 64-dim rotary half."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1000000.0,
        mrope_sections=(16, 24, 24),
        num_patches=256,
        frontend_dim=1280,
        param_dtype="float32",
        compute_dtype="bfloat16",
        seq_parallel_activations=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        mrope_sections=(4, 2, 2),
        num_patches=16,
        frontend_dim=32,
        attn_block_size=32,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
