"""deepseek-coder-33b [arXiv:2401.14196; hf] — llama-arch.
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        num_layers=62,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
        rope_theta=100000.0,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        seq_parallel_activations=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b-smoke",
        family="dense",
        num_layers=2,
        d_model=56,
        num_heads=7,
        num_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        attn_block_size=32,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
