"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32e top-8.
Expert-parallel (32 experts / 16-way model axis = 2 per device)."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        num_experts=32,
        top_k=8,
        moe_style="ep",
        rope_theta=10000.0,
        param_dtype="float32",
        compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=32,
        vocab_size=256,
        num_experts=8,
        top_k=2,
        moe_style="ep",
        attn_block_size=64,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
