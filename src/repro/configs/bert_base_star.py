"""The paper's own evaluation model: BERT-base (12L, d=768, 12H, ff=3072).

The paper profiles softmax latency and accuracy on BERT-base over CNEWS /
MRPC / CoLA.  We carry it as a causal-LM-shaped config for the framework
plus a bidirectional encoder classifier built from the same layers inside
``benchmarks/accuracy_bitwidth.py`` (the paper's accuracy protocol)."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="bert-base-star",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=30522,
        mlp_type="gelu",
        param_dtype="float32",
        compute_dtype="float32",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="bert-base-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        mlp_type="gelu",
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
