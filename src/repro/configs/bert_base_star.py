"""The paper's own evaluation model: BERT-base (12L, d=768, 12H, ff=3072).

The paper profiles softmax latency and accuracy on BERT-base over CNEWS /
MRPC / CoLA.  We carry it as a causal-LM-shaped config for the framework
plus a bidirectional encoder classifier built from the same layers inside
``benchmarks/accuracy_bitwidth.py`` (the paper's accuracy protocol).

The softmax precision is the named policy ``"auto:cnews"`` — resolved
through ``core.precision.policy_for`` at dispatch time, i.e. the paper's
own calibrated per-dataset format table, carried symbolically in the
config instead of as loose bit-count fields."""

from repro.configs.base import ModelConfig
from repro.ops import SoftmaxSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="bert-base-star",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=30522,
        mlp_type="gelu",
        softmax=SoftmaxSpec(kind="star", mode="histogram", precision="auto:cnews"),
        param_dtype="float32",
        compute_dtype="float32",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="bert-base-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        mlp_type="gelu",
        softmax=SoftmaxSpec(kind="star", mode="histogram", precision="auto:cnews"),
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
