"""Model / run configuration dataclasses shared by all architectures.

Op selection lives in ``repro.ops`` specs: a config carries an optional
:class:`~repro.ops.specs.SoftmaxSpec` / :class:`~repro.ops.specs.AttentionSpec`
pair (the canonical form — see ``bert_base_star.py`` / ``granite_8b.py``),
and the legacy loose fields (``softmax_kind`` / ``softmax_mode`` /
``attn_impl`` / ...) survive as deprecated constructor inputs that the
``softmax_spec`` / ``attention_spec`` properties fold into specs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.attention import SoftmaxConfig
from repro.core.fixedpoint import FixedPointFormat
from repro.ops.specs import AttentionSpec, PagedAttentionSpec, SoftmaxSpec

# legacy attn_impl names -> registry impls (new names pass through)
_ATTN_IMPLS = {"naive": "reference", "blocked": "xla", "flash": "pallas"}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    mlp_type: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False

    # --- the paper's technique (repro.ops dispatch) ---
    # Canonical form: specs.  ``softmax`` governs every softmax in the
    # model (attention rows, MoE router, output sampling); ``attention``
    # picks the attention backend + blocking and, when set, fully
    # describes the op (including its nested softmax).
    softmax: Optional[SoftmaxSpec] = None
    attention: Optional[AttentionSpec] = None
    star_router: bool = True  # STAR softmax on the MoE router too
    # Deprecated loose fields (used only when the specs above are None).
    softmax_kind: str = "star"  # star | star_ste | exact
    softmax_int_bits: int = 6
    softmax_frac_bits: int = 2
    softmax_mode: str = "gather"  # gather | onehot | histogram
    attn_impl: str = "blocked"  # naive/reference | blocked/xla | flash/pallas
    attn_block_size: int = 512
    # decode KV-cache write: "dus" (dynamic_update_slice) or "onehot"
    # (masked blend).  With the cache seq dim sharded for SP decode, a
    # dynamic update at a traced index makes XLA reshard the whole cache
    # (collective-permute storm); the one-hot blend is elementwise and
    # stays local — the §Perf decode hillclimb lever.
    kv_update: str = "dus"

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_style: str = "tp"  # tp (expert weights column-parallel) | ep (expert-parallel)

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_ngroups: int = 1

    # --- hybrid (recurrentgemma) ---
    block_pattern: Tuple[str, ...] = ()  # e.g. ("recurrent", "recurrent", "attention")
    lru_width: Optional[int] = None
    local_window: int = 2048
    conv_width: int = 4

    # --- enc-dec (seamless) ---
    num_decoder_layers: int = 0
    frontend_dim: Optional[int] = None  # stub frame/patch embedding dim

    # --- vlm ---
    num_patches: int = 0  # stub patch positions prepended
    mrope_sections: Tuple[int, ...] = ()  # M-RoPE split of head_dim

    # --- numerics / training ---
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    # Megatron-style sequence parallelism on the inter-block activations:
    # the remat-saved layer carries shard their seq dim over the model axis
    # (mandatory for the >=30B configs — 126 saved carries of a 405B model
    # do not fit HBM replicated)
    seq_parallel_activations: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/unembedding table size: vocab padded to a multiple of
        512 so the vocab dim always shards on the model axis (a 50280-size
        table would silently replicate 13 GB/dev of logits otherwise).
        Padded logit columns are masked to -inf in ``unembed``."""
        return -(-self.vocab_size // 512) * 512

    @property
    def softmax_format(self) -> FixedPointFormat:
        fmt = self.softmax_spec.fmt
        if fmt is not None:
            return fmt
        return FixedPointFormat(self.softmax_int_bits, self.softmax_frac_bits)

    @property
    def softmax_spec(self) -> SoftmaxSpec:
        """The softmax contract for this model (repro.ops dispatch).

        Resolution: the ``softmax`` spec field if set, else the nested
        softmax of the ``attention`` spec, else a spec built from the
        legacy loose fields.  Legacy fields moved off their defaults still
        win over a carried spec, so ``dataclasses.replace(cfg,
        softmax_kind="exact")`` (the test idiom) works on every config.
        """
        base = self.softmax
        if base is None and self.attention is not None:
            base = self.attention.softmax
        if base is None:
            return SoftmaxSpec(
                kind=self.softmax_kind,
                mode=self.softmax_mode,
                precision=FixedPointFormat(
                    self.softmax_int_bits, self.softmax_frac_bits
                ),
            )
        updates = {}
        if self.softmax_kind != "star":
            updates["kind"] = self.softmax_kind
        if self.softmax_mode != "gather":
            updates["mode"] = self.softmax_mode
        if (self.softmax_int_bits, self.softmax_frac_bits) != (6, 2):
            updates["precision"] = FixedPointFormat(
                self.softmax_int_bits, self.softmax_frac_bits
            )
        return dataclasses.replace(base, **updates) if updates else base

    @property
    def attention_spec(self) -> AttentionSpec:
        """The attention contract (causal/window/ragged applied per call)."""
        if self.attention is None:
            return AttentionSpec(
                impl=_ATTN_IMPLS.get(self.attn_impl, self.attn_impl),
                softmax=self.softmax_spec,
                block_q=min(self.attn_block_size, 128),
                block_k=min(self.attn_block_size, 128),
                block_kv=self.attn_block_size,
            )
        # legacy-field overrides applied on top of a carried spec (the
        # dataclasses.replace(cfg, attn_...=...) test idiom)
        updates = {"softmax": self.softmax_spec}
        if self.attn_impl != "blocked":
            updates["impl"] = _ATTN_IMPLS.get(self.attn_impl, self.attn_impl)
        if self.attn_block_size != 512:
            updates["block_q"] = min(self.attn_block_size, 128)
            updates["block_k"] = min(self.attn_block_size, 128)
            updates["block_kv"] = self.attn_block_size
        return dataclasses.replace(self.attention, **updates)

    @property
    def paged_attention_spec(self) -> PagedAttentionSpec:
        """The paged-decode contract derived from the attention spec.

        The backend follows the attention impl where the mapping is
        meaningful: ``reference``/``xla`` keep their gather adapters,
        ``pallas`` maps to the gather-free ``pallas_paged`` decode kernel
        (DESIGN.md §11) — the fused path on both sides of the layout.  The
        ``"paged"`` marker impl and anything custom fall back to ``"xla"``
        — the marker selects the *cache layout*, the paged op picks its
        own math backend (overridable via ``ops.use(paged_attention=...)``).
        """
        base = self.attention_spec
        impl = {"reference": "reference", "xla": "xla",
                "pallas": "pallas_paged"}.get(base.impl, "xla")
        return PagedAttentionSpec(
            impl=impl,
            softmax=base.softmax,
            block_q=base.block_q,
            block_k=base.block_k,
            interpret=base.interpret,
        )

    @property
    def softmax_config(self) -> SoftmaxConfig:
        """Deprecated: the pre-dispatch config object (core.attention)."""
        return SoftmaxConfig.from_spec(self.softmax_spec)

    def validate(self) -> "ModelConfig":
        assert self.num_heads % self.num_kv_heads == 0, "GQA divisibility"
        if self.family == "moe":
            assert self.num_experts > 0 and self.top_k > 0
        if self.family == "ssm":
            assert self.ssm_state > 0
        if self.family == "hybrid":
            assert self.block_pattern
        if self.family == "encdec":
            assert self.num_decoder_layers > 0
        return self


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
