"""Architecture configs (assigned pool + the paper's own BERT-base proxy).

Each module exposes ``config()`` (the exact assigned spec) and
``smoke_config()`` (a reduced same-family config for CPU tests).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig  # noqa: F401

ARCH_IDS: List[str] = [
    "granite_moe_1b_a400m",
    "mixtral_8x22b",
    "granite_8b",
    "qwen2_72b",
    "deepseek_coder_33b",
    "llama3_405b",
    "qwen2_vl_7b",
    "mamba2_130m",
    "seamless_m4t_large_v2",
    "recurrentgemma_2b",
]

# long_500k runs only for sub-quadratic archs (DESIGN.md §5)
LONG_CONTEXT_ARCHS = {"mixtral_8x22b", "mamba2_130m", "recurrentgemma_2b"}


def _mod(arch: str):
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch.replace("-", "_")).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch.replace("-", "_")).smoke_config()


def shapes_for(arch: str) -> List[str]:
    arch = arch.replace("-", "_")
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out


def all_cells() -> List[tuple]:
    """Every runnable (arch, shape) dry-run cell."""
    return [(a, s) for a in ARCH_IDS for s in shapes_for(a)]
