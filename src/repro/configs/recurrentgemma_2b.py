"""recurrentgemma-2b [arXiv:2402.19427; hf] — RG-LRU + local attn, 1:2.
26L d_model=2560 10H (GQA kv=1 = MQA) d_ff=7680 vocab=256000.
Pattern (recurrent, recurrent, attention): 8 scanned periods + 2-layer tail.
Local attention window 2048 -> long_500k eligible."""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        block_pattern=("recurrent", "recurrent", "attention"),
        lru_width=2560,
        local_window=2048,
        conv_width=4,
        rope_theta=10000.0,
        param_dtype="float32",
        compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        num_layers=5,  # 1 period + 2-layer tail
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        block_pattern=("recurrent", "recurrent", "attention"),
        lru_width=64,
        local_window=16,
        conv_width=4,
        attn_block_size=32,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
