"""Metrics: Counter / Gauge / Histogram behind a labeled registry.

The counterpart to :mod:`repro.obs.trace` (DESIGN.md §10): traces answer
*where did this request's time go*, metrics answer *what is the fleet
doing* — request rates, queue depths, block-pool occupancy, latency
percentiles — as a ``snapshot()`` dict cheap enough to merge into
``ContinuousBatchingEngine.stats()`` every call.

* :class:`Counter` — monotonically increasing per label-set
  (``c.inc(op="softmax", impl="pallas")``).
* :class:`Gauge` — last-write-wins level (queue depth, slot occupancy).
* :class:`Histogram` — fixed log-spaced buckets (:func:`log_buckets`):
  observations land in geometric bins so one layout spans microseconds
  to minutes with bounded relative error; ``sum``/``min``/``max`` are
  kept exactly, ``percentile(p)`` interpolates within the bucket.  Fixed
  buckets (vs. reservoirs) make merging and snapshotting allocation-free
  and deterministic — the same observations always produce the same
  percentile estimate.
* :class:`MetricsRegistry` — name -> metric, get-or-create with kind
  checking, ``snapshot() -> dict``.  Engines own private registries
  (test isolation); module-level producers (``ops.dispatch``, the
  accuracy guard) write to :func:`default_registry`.

Labels are kwargs; a label-set is keyed by its sorted item tuple, so
``inc(a=1, b=2)`` and ``inc(b=2, a=1)`` hit the same series.  Pure
stdlib — never imports jax.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, Any], ...]


def _lkey(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted(labels.items()))


def log_buckets(
    lo: float = 1e-6, hi: float = 100.0, per_decade: int = 5
) -> Tuple[float, ...]:
    """Geometric bucket upper bounds from ``lo`` to at least ``hi``.

    ``per_decade`` bounds the relative quantization error of percentile
    estimates: 5/decade means neighbouring bounds differ by ~1.58x.
    """
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    if per_decade < 1:
        raise ValueError(f"per_decade must be >= 1, got {per_decade}")
    n = math.ceil(per_decade * math.log10(hi / lo))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


DEFAULT_TIME_BUCKETS = log_buckets(1e-6, 100.0, per_decade=5)


class Counter:
    """Monotonic counter, one value per label-set."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({amount})")
        key = _lkey(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: Any) -> float:
        return self._values.get(_lkey(labels), 0.0)

    def snapshot(self) -> List[Dict[str, Any]]:
        return [
            {"labels": dict(k), "value": v}
            for k, v in sorted(self._values.items())
        ]


class Gauge:
    """Last-write-wins level, one value per label-set."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: Any) -> None:
        self._values[_lkey(labels)] = value

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        key = _lkey(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels: Any) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: Any) -> float:
        return self._values.get(_lkey(labels), 0.0)

    def snapshot(self) -> List[Dict[str, Any]]:
        return [
            {"labels": dict(k), "value": v}
            for k, v in sorted(self._values.items())
        ]


class _HistSeries:
    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets  # one extra overflow bucket at the end
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf


class Histogram:
    """Fixed-bucket histogram with exact sum/min/max per label-set.

    ``buckets`` are inclusive upper bounds; an implicit overflow bucket
    catches everything above the last bound.  The default layout is
    log-spaced over seconds (1 µs .. 100 s) — right for the latency
    histograms this subsystem exists for.
    """

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str = "",
        buckets: Optional[Sequence[float]] = None,
    ):
        self.name = name
        self.help = help
        bs = tuple(buckets) if buckets is not None else DEFAULT_TIME_BUCKETS
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram {name}: buckets must strictly increase")
        if not bs:
            raise ValueError(f"histogram {name}: need at least one bucket")
        self.buckets = bs
        self._series: Dict[LabelKey, _HistSeries] = {}

    def _get(self, labels: Dict[str, Any]) -> _HistSeries:
        key = _lkey(labels)
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(len(self.buckets) + 1)
        return s

    def _bucket_index(self, value: float) -> int:
        # linear scan is fine for <=40 buckets and beats bisect's call
        # overhead at the sizes we use; the hot path is host-side anyway
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                return i
        return len(self.buckets)

    def observe(self, value: float, **labels: Any) -> None:
        s = self._get(labels)
        s.counts[self._bucket_index(value)] += 1
        s.count += 1
        s.sum += value
        if value < s.min:
            s.min = value
        if value > s.max:
            s.max = value

    def count(self, **labels: Any) -> int:
        s = self._series.get(_lkey(labels))
        return s.count if s is not None else 0

    def percentile(self, p: float, **labels: Any) -> Optional[float]:
        """Estimate the ``p``-th percentile (0..100) by interpolating
        within the bucket the rank falls into, clamped to the exact
        observed ``[min, max]``.  ``None`` when the series is empty."""
        if not 0 <= p <= 100:
            raise ValueError(f"percentile must be in [0, 100], got {p}")
        s = self._series.get(_lkey(labels))
        if s is None or s.count == 0:
            # zero-count edge: never leak the ±inf min/max sentinels
            return None
        if s.count == 1 or s.min == s.max:
            # one observation (or a constant series) has an exact answer;
            # skipping interpolation keeps ±inf out of the arithmetic even
            # when the single sample sits in the overflow bucket
            return s.min
        rank = p / 100.0 * s.count
        cum = 0
        for i, n in enumerate(s.counts):
            if n == 0:
                continue
            if cum + n >= rank:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = self.buckets[i] if i < len(self.buckets) else s.max
                frac = (rank - cum) / n
                est = lo + (hi - lo) * max(frac, 0.0)
                return min(max(est, s.min), s.max)
            cum += n
        return s.max

    def snapshot(self) -> List[Dict[str, Any]]:
        out = []
        for key, s in sorted(self._series.items()):
            kw = dict(key)
            out.append({
                "labels": kw,
                "count": s.count,
                "sum": s.sum,
                # both bounds need the zero-count guard: an empty series
                # holds the +inf/-inf init sentinels, which are not JSON
                # and must never escape a snapshot
                "min": s.min if s.count else None,
                "max": s.max if s.count else None,
                "p50": self.percentile(50, **kw),
                "p95": self.percentile(95, **kw),
                "p99": self.percentile(99, **kw),
            })
        return out


Metric = Any  # Counter | Gauge | Histogram


class MetricsRegistry:
    """Name -> metric map with get-or-create and kind checking."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    def _get_or_create(self, cls: type, name: str, **kwargs: Any) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kwargs)
        elif not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} is already registered as a {m.kind}, "
                f"not a {cls.kind}"
            )
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help=help)

    def histogram(
        self,
        name: str,
        help: str = "",
        buckets: Optional[Sequence[float]] = None,
    ) -> Histogram:
        return self._get_or_create(Histogram, name, help=help, buckets=buckets)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """{name: {"kind": ..., "series": [...]}} for every metric."""
        return {
            name: {"kind": m.kind, "series": m.snapshot()}
            for name, m in sorted(self._metrics.items())
        }

    def clear(self) -> None:
        self._metrics.clear()


_DEFAULT_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-global registry module-level producers write to
    (``ops.dispatch`` call counters, accuracy-guard counters)."""
    return _DEFAULT_REGISTRY


def set_default_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the global registry (tests); returns the previous one."""
    global _DEFAULT_REGISTRY
    prev, _DEFAULT_REGISTRY = _DEFAULT_REGISTRY, registry
    return prev
