"""``repro.obs`` — the observability subsystem (DESIGN.md §10).

Three pillars, pure stdlib (never imports jax, so the host-side
scheduler/allocator layers can depend on it freely):

* **Tracing** (:mod:`repro.obs.trace`): span context managers and
  explicit begin/end events into a bounded ring buffer; a process-global
  no-op tracer when disabled (one method call, zero recording on the hot
  path); Chrome trace-event JSON export viewable at
  https://ui.perfetto.dev.
* **Metrics** (:mod:`repro.obs.metrics`): ``Counter`` / ``Gauge`` /
  ``Histogram`` (log-spaced fixed buckets, exact sum/min/max) behind a
  labeled :class:`MetricsRegistry` with ``snapshot() -> dict``.
* **Instrumentation** wired through the stack: serve engine request
  lifecycle (TTFT / ITL / queue-wait histograms, prefill/decode spans,
  per-request async tracks), scheduler + block-pool gauges and counters,
  ``ops.dispatch`` per-(op, impl) call counters, and accuracy-guard trip
  events.

    from repro import obs

    tracer = obs.enable_tracing()
    ...  # serve traffic
    tracer.export_chrome("trace.json")      # load in Perfetto
    print(obs.default_registry().snapshot())
"""

from repro.obs.metrics import (  # noqa: F401
    DEFAULT_TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    log_buckets,
    set_default_registry,
)
from repro.obs.trace import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    TraceEvent,
    Tracer,
    disable_tracing,
    enable_tracing,
    get_tracer,
    set_tracer,
)


def reset() -> None:
    """Restore the no-op tracer and empty the global registry (tests)."""
    disable_tracing()
    default_registry().clear()
