"""Tracing: spans + events into a bounded ring buffer, Chrome-trace export.

The serving stack (DESIGN.md §10) needs stage-level visibility — where a
request's time goes between enqueue, admission, prefill, decode ticks, and
finish — without taxing the hot path when nobody is looking.  Two tracer
implementations share one duck-typed surface:

* :class:`Tracer` — records :class:`TraceEvent` rows into a
  ``deque(maxlen=capacity)`` ring buffer (old events fall off, the
  process never grows unbounded) and exports them as Chrome trace-event
  JSON (``chrome://tracing`` / https://ui.perfetto.dev).  The time source
  is injectable (``clock=``, a zero-arg callable returning seconds) so
  tests assert exact timestamps.
* :class:`NullTracer` — the process-global default.  Every method is a
  no-op returning shared singletons: ``span()`` hands back one reusable
  context manager, so a disabled trace point costs one attribute lookup
  and one call — no event object, no timestamp read, no buffer append.

Instrumentation sites hold a tracer reference and call it unconditionally;
sites that would *build* arguments (lists of uids, formatted labels) gate
on ``tracer.enabled`` first.  The global tracer is swapped with
:func:`enable_tracing` / :func:`disable_tracing` / :func:`set_tracer`;
engines capture :func:`get_tracer` at construction.

Event vocabulary (Chrome trace-event ``ph`` codes):

* ``span(name, **args)`` — a complete ``"X"`` event (begin time + dur).
* ``begin(name)`` / ``end(name)`` — explicit ``"B"`` / ``"E"`` pairs for
  regions that cannot be a ``with`` block.
* ``async_begin/async_end(name, id)`` — ``"b"`` / ``"e"`` events keyed by
  ``id``: one open span per *request* across many ticks (each request
  gets its own track in Perfetto).
* ``instant(name, **args)`` — an ``"i"`` marker (preemption, guard trip).
* ``counter(name, **values)`` — a ``"C"`` sample (queue depth, block
  occupancy) rendered as a stacked counter track.

Pure stdlib — this module must never import jax (the serving scheduler
and block pool stay host-side-only and still get instrumented).
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class TraceEvent:
    """One trace-event row (field names mirror the Chrome JSON keys)."""

    name: str
    ph: str  # B | E | X | i | b | e | C
    ts: float  # microseconds since the tracer's epoch
    dur: Optional[float] = None  # X only: span duration in microseconds
    tid: int = 0
    cat: str = "repro"
    id: Optional[int] = None  # async (b/e) correlation id
    args: Optional[Dict[str, Any]] = None

    def to_json(self) -> Dict[str, Any]:
        row: Dict[str, Any] = {
            "name": self.name,
            "ph": self.ph,
            "ts": self.ts,
            "pid": 0,
            "tid": self.tid,
            "cat": self.cat,
        }
        if self.dur is not None:
            row["dur"] = self.dur
        if self.id is not None:
            row["id"] = self.id
        if self.args:
            row["args"] = self.args
        return row


class _Span:
    """Context manager recording one complete ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._now_us()
        return self

    def __exit__(self, *exc: Any) -> bool:
        t1 = self._tracer._now_us()
        self._tracer._append(TraceEvent(
            self._name, "X", self._t0, dur=t1 - self._t0,
            tid=threading.get_ident() & 0xFFFFFF, cat=self._cat,
            args=self._args or None,
        ))
        return False


class Tracer:
    """Recording tracer: bounded ring buffer + Chrome-trace JSON export.

    ``capacity`` bounds the buffer (oldest events are dropped and counted
    in ``dropped``); ``clock`` is a zero-arg callable returning seconds —
    ``time.perf_counter`` by default, a fake clock in tests.  Timestamps
    are microseconds relative to the tracer's construction, which is what
    the Chrome trace-event format expects.
    """

    enabled = True

    def __init__(
        self,
        *,
        capacity: int = 65536,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self._clock = clock
        self._epoch = clock()
        self._buf: deque[TraceEvent] = deque(maxlen=capacity)
        self.dropped = 0

    # -- recording -----------------------------------------------------------

    def _now_us(self) -> float:
        return (self._clock() - self._epoch) * 1e6

    def _append(self, event: TraceEvent) -> None:
        if len(self._buf) == self.capacity:
            self.dropped += 1
        self._buf.append(event)

    def span(self, name: str, *, cat: str = "repro", **args: Any) -> _Span:
        return _Span(self, name, cat, args)

    def begin(self, name: str, *, cat: str = "repro", **args: Any) -> None:
        self._append(TraceEvent(
            name, "B", self._now_us(),
            tid=threading.get_ident() & 0xFFFFFF, cat=cat, args=args or None,
        ))

    def end(self, name: str, *, cat: str = "repro") -> None:
        self._append(TraceEvent(
            name, "E", self._now_us(),
            tid=threading.get_ident() & 0xFFFFFF, cat=cat,
        ))

    def async_begin(self, name: str, id: int, *, cat: str = "request",
                    **args: Any) -> None:
        self._append(TraceEvent(
            name, "b", self._now_us(), cat=cat, id=id, args=args or None,
        ))

    def async_end(self, name: str, id: int, *, cat: str = "request") -> None:
        self._append(TraceEvent(name, "e", self._now_us(), cat=cat, id=id))

    def instant(self, name: str, *, cat: str = "repro", **args: Any) -> None:
        self._append(TraceEvent(
            name, "i", self._now_us(),
            tid=threading.get_ident() & 0xFFFFFF, cat=cat, args=args or None,
        ))

    def counter(self, name: str, *, cat: str = "repro", **values: float) -> None:
        self._append(TraceEvent(
            name, "C", self._now_us(),
            tid=threading.get_ident() & 0xFFFFFF, cat=cat, args=dict(values),
        ))

    # -- introspection / export ----------------------------------------------

    @property
    def events(self) -> List[TraceEvent]:
        return list(self._buf)

    def clear(self) -> None:
        self._buf.clear()
        self.dropped = 0

    def chrome_trace(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON object (viewable in Perfetto)."""
        return {
            "traceEvents": [e.to_json() for e in self._buf],
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def export_chrome(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path``; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
        return path


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op tracer: shared singletons everywhere, nothing recorded.

    ``span()`` returns one preallocated context manager, so an
    instrumented hot loop with tracing disabled pays a method call and
    nothing else — no event objects, no clock reads, no buffer traffic
    (tests/test_obs.py pins this: zero events after a full serve run).
    """

    enabled = False
    events: List[TraceEvent] = []
    dropped = 0

    def span(self, name: str, *, cat: str = "repro", **args: Any) -> _NullSpan:
        return _NULL_SPAN

    def begin(self, name: str, *, cat: str = "repro", **args: Any) -> None:
        pass

    def end(self, name: str, *, cat: str = "repro") -> None:
        pass

    def async_begin(self, name: str, id: int, *, cat: str = "request",
                    **args: Any) -> None:
        pass

    def async_end(self, name: str, id: int, *, cat: str = "request") -> None:
        pass

    def instant(self, name: str, *, cat: str = "repro", **args: Any) -> None:
        pass

    def counter(self, name: str, *, cat: str = "repro", **values: float) -> None:
        pass

    def clear(self) -> None:
        pass

    def chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": [], "displayTimeUnit": "ms",
                "otherData": {"dropped_events": 0}}


NULL_TRACER = NullTracer()

_GLOBAL_TRACER: "Tracer | NullTracer" = NULL_TRACER


def get_tracer() -> "Tracer | NullTracer":
    """The process-global tracer (the no-op singleton unless enabled)."""
    return _GLOBAL_TRACER


def set_tracer(tracer: "Tracer | NullTracer") -> "Tracer | NullTracer":
    """Install ``tracer`` globally; returns the previous one."""
    global _GLOBAL_TRACER
    prev, _GLOBAL_TRACER = _GLOBAL_TRACER, tracer
    return prev


def enable_tracing(
    *,
    capacity: int = 65536,
    clock: Callable[[], float] = time.perf_counter,
) -> Tracer:
    """Install (and return) a fresh recording tracer as the global one."""
    tracer = Tracer(capacity=capacity, clock=clock)
    set_tracer(tracer)
    return tracer


def disable_tracing() -> None:
    """Restore the no-op global tracer."""
    set_tracer(NULL_TRACER)
