"""Deterministic synthetic data pipeline.

A reproducible, shardable token source: a per-(step, shard) seeded mixture
of (a) an order-2 Markov chain over a small latent alphabet projected onto
the vocab and (b) uniform noise.  Learnable structure (so training curves
move) with zero external data dependencies.

Every batch is a pure function of (seed, step, shard) — exactly what a
1000-node data pipeline needs for deterministic restart (the checkpoint
records the step; every host regenerates its shard without coordination).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    latent: int = 64  # Markov alphabet
    noise: float = 0.1
    order: int = 2


def _latent_chain(rng: np.random.Generator, n: int, k: int, order: int, noise: float):
    """Order-`order` Markov chain over k symbols (deterministic transitions +
    noise): next = (a*prev1 + b*prev2 + c) % k with occasional random hops."""
    a, b, c = 5, 7, 3
    seq = np.empty(n, dtype=np.int64)
    seq[:order] = rng.integers(0, k, order)
    hops = rng.random(n) < noise
    rnd = rng.integers(0, k, n)
    for i in range(order, n):
        seq[i] = rnd[i] if hops[i] else (a * seq[i - 1] + b * seq[i - 2] + c) % k
    return seq


def make_batch(
    model_cfg: ModelConfig,
    *,
    batch: int,
    seq_len: int,
    step: int,
    shard: int = 0,
    data_cfg: DataConfig = DataConfig(),
) -> Dict[str, np.ndarray]:
    """One batch: tokens [B, T], labels [B, T] (next-token), plus the stub
    frontend inputs for vlm/encdec families."""
    rng = np.random.default_rng(
        np.random.SeedSequence([data_cfg.seed, step, shard])
    )
    k = min(data_cfg.latent, model_cfg.vocab_size)
    toks = np.stack(
        [
            _latent_chain(rng, seq_len + 1, k, data_cfg.order, data_cfg.noise)
            for _ in range(batch)
        ]
    )
    # project latent onto vocab deterministically (spread over the table)
    stride = max(1, model_cfg.vocab_size // (k + 1))
    toks = (toks * stride) % model_cfg.vocab_size
    out: Dict[str, np.ndarray] = {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }
    if model_cfg.family == "vlm":
        out["patch_embeds"] = rng.standard_normal(
            (batch, model_cfg.num_patches, model_cfg.frontend_dim or model_cfg.d_model),
            dtype=np.float32,
        )
    if model_cfg.family == "encdec":
        out["src_embeds"] = rng.standard_normal(
            (batch, max(8, seq_len // 4), model_cfg.frontend_dim or model_cfg.d_model),
            dtype=np.float32,
        )
    return out


def batch_iterator(
    model_cfg: ModelConfig,
    *,
    batch: int,
    seq_len: int,
    start_step: int = 0,
    shard: int = 0,
    data_cfg: DataConfig = DataConfig(),
) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(
            model_cfg, batch=batch, seq_len=seq_len, step=step, shard=shard,
            data_cfg=data_cfg,
        )
        step += 1
