"""Pure-jnp oracle for the crossbar MatMul engine model.

Behavioral model of the ReTransformer/PipeLayer-style RRAM MatMul engine the
paper builds on (its MatMul engine "follows the design in ReTransformer"):

  * weights quantized to 8-bit ints, stored across 128x128 crossbar tiles;
  * activations quantized to 8-bit ints (multi-bit DAC variant — bit-serial
    DACs at 8-bit input precision change error statistics negligibly and are
    a documented simplification, DESIGN.md §2);
  * each tile's analog partial sum passes a **5-bit ADC** (the paper's
    MatMul engine setting): uniform signed quantization, full-scale range =
    the tile's worst-case column sum;
  * quantized partials accumulate digitally across K tiles.

This is the *baseline accuracy* model used by the benchmarks; the
performance path of the framework uses native MXU matmuls.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.hwmodel import faults as faults_lib
from repro.hwmodel.faults import FaultModel


@dataclasses.dataclass(frozen=True)
class CrossbarSpec:
    tile_rows: int = 128  # crossbar rows (K per tile)
    tile_cols: int = 128  # crossbar cols (N per tile)
    adc_bits: int = 5
    weight_bits: int = 8
    input_bits: int = 8

    @property
    def adc_levels(self) -> int:
        # signed symmetric: [-(2^(b-1)-1), +(2^(b-1)-1)]
        return (1 << (self.adc_bits - 1)) - 1


DEFAULT_SPEC = CrossbarSpec()


def _sym_quant(x: jax.Array, bits: int):
    top = (1 << (bits - 1)) - 1
    s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / top
    q = jnp.clip(jnp.round(x / s), -top, top).astype(jnp.int32)
    return q, s


def quantize_operands(x: jax.Array, w: jax.Array, spec: CrossbarSpec = DEFAULT_SPEC):
    """(xq, sx), (wq, sw) with per-tensor symmetric scales."""
    xq, sx = _sym_quant(x.astype(jnp.float32), spec.input_bits)
    wq, sw = _sym_quant(w.astype(jnp.float32), spec.weight_bits)
    return (xq, sx), (wq, sw)


def _pad_to(a: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if not pad:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def adc_step(
    xq: jax.Array,
    wq: jax.Array,
    spec: CrossbarSpec = DEFAULT_SPEC,
    ranging: str = "calibrated",
) -> jax.Array:
    """Per-(k-tile, n-tile) ADC quantization step, shape [Kt, Nt].

    ``"calibrated"`` (default, NeuroSim-style): range = observed max
    |partial sum| per tile — what a deployed design programs after
    calibration.  ``"fullscale"``: worst-case column-sum range (pessimistic;
    5-bit ADCs are unusable at this setting, included for ablation).
    Operands must already be padded to tile multiples.
    """
    m = xq.shape[0]
    kt = xq.shape[1] // spec.tile_rows
    nt = wq.shape[1] // spec.tile_cols
    xtiles = xq.reshape(m, kt, spec.tile_rows)
    wtiles = wq.reshape(kt, spec.tile_rows, nt, spec.tile_cols)
    if ranging == "fullscale":
        in_top = (1 << (spec.input_bits - 1)) - 1
        fullscale = jnp.max(jnp.sum(jnp.abs(wtiles), axis=1), axis=-1) * in_top
    elif ranging == "calibrated":
        partial = jnp.einsum(
            "mkr,krnc->kmnc", xtiles.astype(jnp.float32), wtiles.astype(jnp.float32)
        )
        fullscale = jnp.max(jnp.abs(partial), axis=(1, 3))  # [kt, nt]
    else:
        raise ValueError(f"unknown ranging {ranging!r}")
    return (jnp.maximum(fullscale, 1.0) / spec.adc_levels).astype(jnp.float32)


def apply_weight_faults(
    wq: jax.Array, spec: CrossbarSpec, fault: Optional[FaultModel]
) -> jax.Array:
    """Perturb the stored (padded, quantized) weight array with cell faults.

    Weights are the programmed conductances: lognormal variation and read
    disturb scale them, stuck-at-G_on reads as the top code ``2^(b-1)-1``
    and stuck-at-G_off as zero (differential-pair sign handling is folded
    into this single-array behavioural view — a documented simplification,
    consistent with the 8-bit single-array quantization above).  Returns
    float32: faulty conductances are off-grid by construction.
    """
    if faults_lib.is_null(fault):
        return wq
    w_top = float((1 << (spec.weight_bits - 1)) - 1)
    return faults_lib.apply_cell_faults(
        wq.astype(jnp.float32), fault, "matmul/w", g_on=w_top, g_off=0.0
    )


def crossbar_matmul_ref(
    x: jax.Array,
    w: jax.Array,
    spec: CrossbarSpec = DEFAULT_SPEC,
    ranging: str = "calibrated",
    fault: Optional[FaultModel] = None,
) -> jax.Array:
    """x [M, K] @ w [K, N] through the crossbar model (float32 out).

    ``fault`` injects seeded device non-idealities (DESIGN.md §9): cell
    faults on the stored weights plus per-tile ADC offsets.  Calibrated
    ranging observes the *faulty* array — a deployed design calibrates
    its ADC ranges after the faults exist.
    """
    m, kdim = x.shape
    _, n = w.shape
    (xq, sx), (wq, sw) = quantize_operands(x, w, spec)

    xq = _pad_to(xq, 1, spec.tile_rows)
    wq = _pad_to(_pad_to(wq, 0, spec.tile_rows), 1, spec.tile_cols)
    wq = apply_weight_faults(wq, spec, fault)
    kt = xq.shape[1] // spec.tile_rows
    nt = wq.shape[1] // spec.tile_cols

    xtiles = xq.reshape(m, kt, spec.tile_rows)
    wtiles = wq.reshape(kt, spec.tile_rows, nt, spec.tile_cols)
    step = adc_step(xq, wq, spec, ranging)  # [kt, nt]
    offsets = faults_lib.adc_tile_offsets(fault, (kt, nt)) if fault else None

    acc = jnp.zeros((m, nt, spec.tile_cols), jnp.float32)
    for k in range(kt):
        partial = jnp.einsum(
            "mr,rnc->mnc", xtiles[:, k].astype(jnp.float32),
            wtiles[k].astype(jnp.float32),
        )  # exact integer-valued partial
        st = step[k][None, :, None]
        code = partial / st
        if offsets is not None:
            code = code + offsets[k][None, :, None]  # input-referred offset
        adc = jnp.clip(jnp.round(code), -spec.adc_levels, spec.adc_levels) * st
        acc = acc + adc
    out = acc.reshape(m, nt * spec.tile_cols)[:, :n]
    return out * (sx * sw)


def exact_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return x.astype(jnp.float32) @ w.astype(jnp.float32)
