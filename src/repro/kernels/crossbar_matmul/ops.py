"""Public wrapper: quantization + ADC calibration + the Pallas kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.crossbar_matmul.ref import (
    CrossbarSpec,
    DEFAULT_SPEC,
    _pad_to,
    adc_step,
    quantize_operands,
)
from repro.kernels.crossbar_matmul.kernel import crossbar_matmul_pallas


def crossbar_matmul_op(
    x: jax.Array,
    w: jax.Array,
    *,
    spec: CrossbarSpec = DEFAULT_SPEC,
    ranging: str = "calibrated",
    block_m: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """x [M, K] @ w [K, N] through the RRAM crossbar behavioural model."""
    m, kdim = x.shape
    _, n = w.shape
    (xq, sx), (wq, sw) = quantize_operands(x, w, spec)
    xq = _pad_to(xq, 1, spec.tile_rows)
    wq = _pad_to(_pad_to(wq, 0, spec.tile_rows), 1, spec.tile_cols)
    step = adc_step(xq, wq, spec, ranging)

    out = crossbar_matmul_pallas(
        xq.astype(jnp.int8) if spec.weight_bits <= 8 else xq,
        wq.astype(jnp.int8) if spec.weight_bits <= 8 else wq,
        step, spec=spec, block_m=block_m, interpret=interpret,
    )
    return out[:, :n] * (sx * sw)
