"""Deprecated shim: use ``repro.ops.matmul`` with a ``MatmulSpec``.

Kept so pre-dispatch call sites keep working unchanged; it folds the old
kwargs into a spec (``impl="hwmodel"`` — the crossbar behavioural model)
and dispatches through the registry.  ``interpret=None`` now means
"platform default".

Scheduled for removal: no in-repo caller imports this shim any more
(pinned by ``tests/test_kv_quant.py::test_no_in_repo_shim_importers``);
it exists solely for out-of-tree call sites and will be deleted in a
future PR.  New code must go through ``repro.ops`` directly.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro import ops
from repro.kernels.crossbar_matmul.ref import DEFAULT_SPEC, CrossbarSpec


def crossbar_matmul_op(
    x: jax.Array,
    w: jax.Array,
    *,
    spec: CrossbarSpec = DEFAULT_SPEC,
    ranging: str = "calibrated",
    block_m: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """x [M, K] @ w [K, N] through the RRAM crossbar behavioural model."""
    from repro.kernels import warn_shim

    warn_shim(
        "repro.kernels.crossbar_matmul.ops.crossbar_matmul_op",
        "repro.ops.matmul with a MatmulSpec(impl='hwmodel')",
    )
    return ops.matmul(
        x,
        w,
        ops.MatmulSpec(
            impl="hwmodel",
            crossbar=spec,
            ranging=ranging,
            block_m=block_m,
            interpret=interpret,
        ),
    )
