"""Pallas kernel for the crossbar MatMul engine model (ref.py semantics).

Grid ``(M/bm, N/tile_cols, K/tile_rows)`` — K innermost so the f32
accumulator scratch carries quantized partial sums across crossbar K-tiles,
exactly like the digital accumulator behind the ADCs.  The per-tile ADC
step array is computed in ops.py (calibration) and streamed per grid cell.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.crossbar_matmul.ref import CrossbarSpec, DEFAULT_SPEC


def _kernel(x_ref, w_ref, step_ref, off_ref, o_ref, acc, *, adc_levels: int):
    kt = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kt == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    partial = jax.lax.dot_general(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    st = step_ref[0, 0]
    # per-tile ADC input-referred offset (fault injection; zero when ideal)
    code = partial / st + off_ref[0, 0]
    adc = jnp.clip(jnp.round(code), -adc_levels, adc_levels) * st
    acc[...] += adc

    @pl.when(kt == nk - 1)
    def _():
        o_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("spec", "block_m", "interpret"))
def crossbar_matmul_pallas(
    xq: jax.Array,  # int8/int32 quantized activations [M, K], K % tile_rows == 0
    wq: jax.Array,  # quantized weights [K, N], N % tile_cols == 0 (f32 if faulty)
    step: jax.Array,  # f32 [Kt, Nt] ADC step per crossbar tile
    offsets: jax.Array | None = None,  # f32 [Kt, Nt] ADC offsets in LSB (faults)
    *,
    spec: CrossbarSpec = DEFAULT_SPEC,
    block_m: int = 128,
    interpret: bool = True,
) -> jax.Array:
    m, kdim = xq.shape
    _, n = wq.shape
    ktiles = kdim // spec.tile_rows
    ntiles = n // spec.tile_cols
    if offsets is None:
        offsets = jnp.zeros((ktiles, ntiles), jnp.float32)
    bm = min(block_m, m)
    pad_m = (-m) % bm
    if pad_m:
        xq = jnp.pad(xq, ((0, pad_m), (0, 0)))
    mt = (m + pad_m) // bm

    out = pl.pallas_call(
        functools.partial(_kernel, adc_levels=spec.adc_levels),
        out_shape=jax.ShapeDtypeStruct((m + pad_m, n), jnp.float32),
        grid=(mt, ntiles, ktiles),
        in_specs=[
            pl.BlockSpec((bm, spec.tile_rows), lambda i, j, k: (i, k)),
            pl.BlockSpec((spec.tile_rows, spec.tile_cols), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, spec.tile_cols), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, spec.tile_cols), jnp.float32)],
        interpret=interpret,
    )(xq, wq, step, offsets)
    return out[:m]
