"""Pallas TPU kernel for the STAR softmax engine.

Grid walks row tiles; each tile ``(block_rows, d)`` lives in VMEM.  Inside a
tile the engine stages map to TPU units (DESIGN.md §2):

  CAM max search   -> int32 row max over the quantized grid      (VPU)
  SUB + CAM match  -> k = clip(m - j, 0, L-1)                    (VPU)
  LUT crossbar     -> p = exp(-k / scale): codebook entry,
                      evaluated arithmetically on the VPU (bit-equal to the
                      table up to 1 ulp), or via one-hot @ lut on the MXU
                      when ``use_mxu_lut=True`` (the faithful crossbar
                      dataflow; costs FLOPs, saves nothing on TPU — kept for
                      dataflow validation)
  counter + VMM    -> denominator via histogram @ lut (MXU) when
                      ``use_histogram=True``, else a plain row sum (VPU)
  divider          -> reciprocal-multiply                        (VPU)

The quantized index tile is emitted alongside the probabilities when
``emit_indices=True`` so downstream int8 P·V consumers can reuse the CAM
match without requantizing.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fixedpoint import FixedPointFormat
from repro.hwmodel import faults as faults_lib
from repro.hwmodel.faults import FaultModel


def _kernel(
    x_ref,
    o_ref,
    *,
    fmt: FixedPointFormat,
    use_histogram: bool,
    use_mxu_lut: bool,
):
    x = x_ref[...].astype(jnp.float32)  # (br, d)
    br, d = x.shape
    nl = fmt.num_levels
    scale = jnp.float32(fmt.scale)

    # CAM-at-input quantization onto the signed integer grid.
    j = jnp.round(x * scale).astype(jnp.int32)
    m = jnp.max(j, axis=-1, keepdims=True)  # CAM max search
    k = jnp.clip(m - j, 0, nl - 1)  # SUB + match index (>= 0)

    if use_mxu_lut:
        # Faithful crossbar dataflow: one-hot match matrix x LUT column (MXU).
        levels = jax.lax.broadcasted_iota(jnp.int32, (br, d, nl), 2)
        onehot = (levels == k[..., None]).astype(jnp.float32)
        lut = jnp.exp(-jax.lax.broadcasted_iota(jnp.float32, (nl, 1), 0) / scale)
        p = jax.lax.dot_general(
            onehot.reshape(br * d, nl), lut,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(br, d)
    else:
        # VPU form: evaluate the codebook entry arithmetically.
        p = jnp.exp(-k.astype(jnp.float32) / scale)

    if use_histogram:
        # counter + VMM: histogram the match indices, then one small VMM.
        levels = jax.lax.broadcasted_iota(jnp.int32, (br, d, nl), 2)
        counts = jnp.sum((levels == k[..., None]).astype(jnp.float32), axis=1)
        lut = jnp.exp(-jax.lax.broadcasted_iota(jnp.float32, (nl, 1), 0) / scale)
        den = jax.lax.dot_general(
            counts, lut, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (br, 1)
    else:
        den = jnp.sum(p, axis=-1, keepdims=True)

    o_ref[...] = (p / den).astype(o_ref.dtype)


def _kernel_faulty(
    x_ref,
    lut_ref,  # (L, 1) faulty numerator LUT column
    vmm_ref,  # (L, 1) faulty denominator VMM column
    remap_ref,  # (L, 1) CAM match remap (float-coded indices)
    o_ref,
    *,
    fmt: FixedPointFormat,
    use_histogram: bool,
):
    """Fault-injected variant: the LUT/VMM contents and the CAM remap are
    *runtime operands* (a seeded realization computed at trace time), so
    the codebook can no longer be evaluated arithmetically.  Every lookup
    is a one-hot matmul — the faithful crossbar dataflow, and exact (a
    single-nonzero dot reproduces the gathered entry bit-for-bit)."""
    x = x_ref[...].astype(jnp.float32)  # (br, d)
    br, d = x.shape
    nl = fmt.num_levels
    scale = jnp.float32(fmt.scale)

    j = jnp.round(x * scale).astype(jnp.int32)
    m = jnp.max(j, axis=-1, keepdims=True)  # CAM max search
    k = jnp.clip(m - j, 0, nl - 1)  # SUB + match index

    levels = jax.lax.broadcasted_iota(jnp.int32, (br, d, nl), 2)
    onehot = (levels == k[..., None]).astype(jnp.float32)
    # broken CAM rows match the nearest working row: k' = onehot(k) @ remap
    k2 = jax.lax.dot_general(
        onehot.reshape(br * d, nl), remap_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(br, d).astype(jnp.int32)
    onehot2 = (levels == k2[..., None]).astype(jnp.float32)
    p = jax.lax.dot_general(
        onehot2.reshape(br * d, nl), lut_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(br, d)

    if use_histogram:
        counts = jnp.sum(onehot2, axis=1)  # (br, nl)
        den = jax.lax.dot_general(
            counts, vmm_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (br, 1)
    else:
        den = jnp.sum(p, axis=-1, keepdims=True)

    den = jnp.where(den <= 0.0, 1.0, den)  # fully-stuck-off rows -> zeros
    o_ref[...] = (p / den).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "fmt", "block_rows", "use_histogram", "use_mxu_lut", "interpret",
        "fault",
    ),
)
def star_softmax_pallas(
    x: jax.Array,
    *,
    fmt: FixedPointFormat,
    block_rows: int = 8,
    use_histogram: bool = False,
    use_mxu_lut: bool = False,
    interpret: bool = True,
    fault: Optional[FaultModel] = None,
) -> jax.Array:
    """STAR softmax over the last axis of ``x`` (any leading shape).

    Rows are padded to a multiple of ``block_rows``; the full feature dim
    lives in one VMEM tile (use ``flash_star`` for attention-scale rows).

    ``fault`` (static, hashable) switches to the fault-injected kernel:
    the seeded CAM/LUT/VMM realizations stream in as operands and the ADC
    denominator gain applies on the way out (DESIGN.md §9).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    padded_rows = rows + pad
    grid = (padded_rows // block_rows,)
    out_shape = jax.ShapeDtypeStruct((padded_rows, d), jnp.float32)
    block = pl.BlockSpec((block_rows, d), lambda i: (i, 0))

    if faults_lib.is_null(fault):
        out = pl.pallas_call(
            functools.partial(
                _kernel, fmt=fmt, use_histogram=use_histogram,
                use_mxu_lut=use_mxu_lut,
            ),
            out_shape=out_shape,
            grid=grid,
            in_specs=[block],
            out_specs=block,
            interpret=interpret,
        )(x2)
        return out[:rows].reshape(orig_shape)

    nl = fmt.num_levels
    lut = faults_lib.faulty_exp_lut(fmt, fault, tag="softmax/lut")
    vmm = (
        faults_lib.faulty_exp_lut(fmt, fault, tag="softmax/vmm")
        if use_histogram
        else lut
    )
    remap = faults_lib.cam_remap(fmt, fault)
    if remap is None:
        remap = jnp.arange(nl, dtype=jnp.int32)
    table_spec = pl.BlockSpec((nl, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        functools.partial(
            _kernel_faulty, fmt=fmt, use_histogram=use_histogram
        ),
        out_shape=out_shape,
        grid=grid,
        in_specs=[block, table_spec, table_spec, table_spec],
        out_specs=block,
        interpret=interpret,
    )(
        x2,
        lut.reshape(nl, 1),
        vmm.reshape(nl, 1),
        remap.astype(jnp.float32).reshape(nl, 1),
    )
    out = out[:rows].reshape(orig_shape)
    if use_histogram:
        gain = faults_lib.adc_gain(fault)
        if gain is not None:
            # den' = den * gain  =>  out' = out / gain (gain applied to the
            # whole row uniformly — hoisting it out keeps the kernel clean)
            out = out / gain
    return out
