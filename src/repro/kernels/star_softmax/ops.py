"""Public jit'd wrapper for the STAR softmax Pallas kernel.

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware pass ``interpret=False`` (the launcher does this via
``repro.launch`` when it detects TPU devices).
"""

from __future__ import annotations

import jax

from repro.core.fixedpoint import DEFAULT_FORMAT, FixedPointFormat
from repro.kernels.star_softmax.kernel import star_softmax_pallas


def star_softmax_op(
    x: jax.Array,
    fmt: FixedPointFormat = DEFAULT_FORMAT,
    *,
    block_rows: int = 8,
    use_histogram: bool = False,
    use_mxu_lut: bool = False,
    interpret: bool = True,
) -> jax.Array:
    return star_softmax_pallas(
        x,
        fmt=fmt,
        block_rows=block_rows,
        use_histogram=use_histogram,
        use_mxu_lut=use_mxu_lut,
        interpret=interpret,
    )
