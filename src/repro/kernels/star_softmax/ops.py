"""Deprecated shim: use ``repro.ops.softmax`` with a ``SoftmaxSpec``.

Kept so pre-dispatch call sites keep working unchanged; it simply folds
the old kwargs into a spec and dispatches through the registry.
``interpret=None`` now means "platform default" (TPU compiles, everything
else interprets) instead of the old hardcoded ``True``.

Scheduled for removal: no in-repo caller imports this shim any more
(pinned by ``tests/test_kv_quant.py::test_no_in_repo_shim_importers``);
it exists solely for out-of-tree call sites and will be deleted in a
future PR.  New code must go through ``repro.ops`` directly.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro import ops
from repro.core.fixedpoint import DEFAULT_FORMAT, FixedPointFormat


def star_softmax_op(
    x: jax.Array,
    fmt: FixedPointFormat = DEFAULT_FORMAT,
    *,
    block_rows: int = 8,
    use_histogram: bool = False,
    use_mxu_lut: bool = False,
    interpret: Optional[bool] = None,
) -> jax.Array:
    from repro.kernels import warn_shim

    warn_shim(
        "repro.kernels.star_softmax.ops.star_softmax_op",
        "repro.ops.softmax with a SoftmaxSpec(impl='pallas')",
    )
    if use_histogram and use_mxu_lut:
        # The spec contract has three *exclusive* dataflow modes; the old
        # kernel flags were orthogonal.  Preserve the legacy combination
        # (one-hot MXU numerator + histogram denominator) bit-exactly by
        # calling the kernel directly — new code wanting this dataflow
        # should register a backend for it.
        from repro.kernels.star_softmax.kernel import star_softmax_pallas

        return star_softmax_pallas(
            x,
            fmt=fmt,
            block_rows=block_rows,
            use_histogram=True,
            use_mxu_lut=True,
            interpret=ops.resolve_interpret(interpret),
        )
    mode = "histogram" if use_histogram else ("onehot" if use_mxu_lut else "gather")
    return ops.softmax(
        x,
        ops.SoftmaxSpec(
            impl="pallas",
            kind="star",
            mode=mode,
            precision=fmt,
            block_rows=block_rows,
            interpret=interpret,
        ),
    )
