"""Pure-jnp oracle for the STAR softmax kernel.

The kernel must match this reference (which in turn is the two-pass
``repro.core.star_softmax``) to float32 rounding: the kernel evaluates LUT
entries arithmetically (``exp`` of the dequantized index, on the VPU) while
the reference gathers from the prebuilt table — identical codebook values up
to 1 ulp of libm vs XLA exp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import DEFAULT_FORMAT, FixedPointFormat
from repro.core.star_softmax import star_softmax


def star_softmax_ref(
    x: jax.Array,
    fmt: FixedPointFormat = DEFAULT_FORMAT,
    *,
    mode: str = "gather",
) -> jax.Array:
    """Two-pass STAR softmax over the last axis (float32 out)."""
    return star_softmax(x, fmt, axis=-1, mode=mode, dtype=jnp.float32)


def exact_softmax_ref(x: jax.Array) -> jax.Array:
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1)
