"""Pure-jnp oracle for flash_star: the core attention paths.

``flash_star`` must match ``blocked_attention`` (the lax.scan vector
pipeline) to float32 rounding, and ``attention`` (whole-operand two-pass)
to the same tolerance — the integer-grid STAR arithmetic makes all three
forms numerically identical up to summation order.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.core.attention import SoftmaxConfig, attention, blocked_attention
from repro.core.fixedpoint import DEFAULT_FORMAT, FixedPointFormat


def _cfg(fmt: Optional[FixedPointFormat]) -> SoftmaxConfig:
    if fmt is None:
        return SoftmaxConfig(kind="exact")
    return SoftmaxConfig(kind="star", fmt=fmt, mode="gather")


def flash_star_ref(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    fmt: Optional[FixedPointFormat] = DEFAULT_FORMAT,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    kv_valid_len=None,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Two-pass whole-operand reference."""
    return attention(
        q, k, v, softmax=_cfg(fmt), causal=causal,
        sliding_window=sliding_window, q_offset=q_offset,
        kv_valid_len=kv_valid_len, scale=sm_scale,
    )


def flash_star_blocked_ref(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    fmt: Optional[FixedPointFormat] = DEFAULT_FORMAT,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    kv_valid_len=None,
    sm_scale: Optional[float] = None,
    block_size: int = 128,
) -> jax.Array:
    """Online lax.scan reference (same schedule as the kernel)."""
    return blocked_attention(
        q, k, v, softmax=_cfg(fmt), causal=causal,
        sliding_window=sliding_window, q_offset=q_offset,
        kv_valid_len=kv_valid_len, scale=sm_scale, block_size=block_size,
    )
