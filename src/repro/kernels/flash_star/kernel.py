"""flash_star — fused blocked attention with the STAR softmax engine.

This is the paper's **vector-grained global pipeline** (§II, last ¶) in its
TPU-native form: instead of three crossbar engines pipelining QKᵀ → softmax
→ P·V per attention vector, one Pallas kernel walks KV blocks with the three
stages fused in VMEM; the Pallas grid's DMA double-buffering overlaps the
HBM→VMEM load of block *i+1* with the compute of block *i* — the crossbar
pipeline's overlap, realized by the TPU memory system.

STAR arithmetic is the integer-grid online form (DESIGN.md §2): scores are
snapped to the codebook grid once, the running max is an int32, the rescale
factor is a codebook entry, and the result equals the two-pass engine to
float32 rounding.

Grid: ``(B, Hq, num_q_blocks, num_kv_blocks)`` — KV innermost so the
``(m, s, acc)`` VMEM scratch carries across KV steps of one q block.
Causal / sliding-window / ragged-KV blocks are predicated off with
``pl.when`` (on real TPU this skips the MXU work of fully-masked blocks).

Beyond-paper: ``pv_int8=True`` quantizes P (already a ≤2^b-value codebook —
the paper's own observation) *and* V per block to int8 and runs P·V on the
int8 MXU path (2x bf16 MXU throughput on v5e, half the VMEM traffic for P).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fixedpoint import GRID_SENTINEL, FixedPointFormat

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _kernel(
    info_ref,  # int32 [1 + B]: [q_offset, kv_valid_len_0, ...]
    q_ref,  # (1, 1, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    o_ref,  # (1, 1, bq, D)
    m_scr,  # (bq,) int32 (star) / f32 (exact)
    s_scr,  # (bq,) f32
    acc_scr,  # (bq, D) f32
    *,
    fmt: Optional[FixedPointFormat],
    causal: bool,
    sliding_window: Optional[int],
    kv_len: int,
    sm_scale: float,
    pv_int8: bool,
):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)
    star = fmt is not None

    bq = q_ref.shape[2]
    bk = k_ref.shape[2]
    q_offset = info_ref[0]
    kv_valid = info_ref[1 + b]

    @pl.when(ik == 0)
    def _init():
        if star:
            m_scr[...] = jnp.full_like(m_scr, GRID_SENTINEL)
        else:
            m_scr[...] = jnp.full_like(m_scr, -1e30)
        s_scr[...] = jnp.zeros_like(s_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level skip: the whole KV block is masked out.
    row0 = iq * bq + q_offset  # absolute position of first q row
    col0 = ik * bk
    live = col0 < kv_valid
    if causal:
        live &= col0 <= row0 + (bq - 1)
    if sliding_window is not None:
        live &= (col0 + bk - 1) > (row0 - sliding_window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (bq, bk)

        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < jnp.minimum(kv_valid, kv_len)
        if causal:
            mask &= cols <= rows
        if sliding_window is not None:
            mask &= cols > rows - sliding_window

        if star:
            nl = fmt.num_levels
            scale_fp = jnp.float32(fmt.scale)
            jgrid = jnp.where(
                mask, jnp.round(s * scale_fp).astype(jnp.int32), GRID_SENTINEL
            )
            m_blk = jnp.max(jgrid, axis=-1)  # (bq,) int32
            m_old = m_scr[...]
            m_new = jnp.maximum(m_old, m_blk)
            shift = jnp.clip(m_new - m_old, 0, nl - 1)
            r = jnp.exp(-shift.astype(jnp.float32) / scale_fp)  # LUT entry
            kidx = jnp.clip(m_new[:, None] - jgrid, 0, nl - 1)
            p = jnp.exp(-kidx.astype(jnp.float32) / scale_fp)  # LUT entries
            p = jnp.where(mask, p, 0.0)
            m_scr[...] = m_new
        else:
            s = jnp.where(mask, s, -1e30)
            m_blk = jnp.max(s, axis=-1)
            m_old = m_scr[...]
            m_new = jnp.maximum(m_old, m_blk)
            r = jnp.exp(m_old - m_new)
            p = jnp.exp(s - m_new[:, None])
            p = jnp.where(mask, p, 0.0)
            m_scr[...] = m_new

        if pv_int8:
            # P is a codebook: <= 2^b distinct values in (0, 1] -> int8
            # mantissas are near-lossless for the mass that matters.  V is
            # quantized per block with a dynamic scale.  P·V hits the int8
            # MXU path (2x bf16 throughput on v5e).
            p8 = jnp.round(p * 127.0).astype(jnp.int8)
            vf = v.astype(jnp.float32)
            vamax = jnp.maximum(jnp.max(jnp.abs(vf)), 1e-6)
            v8 = jnp.round(vf * (127.0 / vamax)).astype(jnp.int8)
            pv32 = jax.lax.dot_general(
                p8, v8, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            pv = pv32.astype(jnp.float32) * (vamax / (127.0 * 127.0))
        else:
            pv = jax.lax.dot_general(
                p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

        s_scr[...] = s_scr[...] * r + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * r[:, None] + pv

    @pl.when(ik == nk - 1)
    def _finalize():
        den = s_scr[...]
        den = jnp.where(den <= 0.0, 1.0, den)
        o_ref[0, 0] = (acc_scr[...] / den[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "fmt", "causal", "sliding_window", "sm_scale",
        "block_q", "block_k", "pv_int8", "interpret",
    ),
)
def flash_star_attention(
    q: jax.Array,  # [B, Hq, Tq, D]
    k: jax.Array,  # [B, Hkv, Tk, D]
    v: jax.Array,  # [B, Hkv, Tk, D]
    info: jax.Array,  # int32 [1 + B]: [q_offset, kv_valid_len per batch]
    *,
    fmt: Optional[FixedPointFormat],  # None -> exact softmax (baseline)
    causal: bool = True,
    sliding_window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    pv_int8: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Fused attention, heads-major layout.  Returns [B, Hq, Tq, D]."""
    batch, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0, "GQA needs Hq % Hkv == 0"
    group = hq // hkv
    sm_scale = (d ** -0.5) if sm_scale is None else sm_scale

    bq = min(block_q, tq)
    bk = min(block_k, tk)
    pad_q = (-tq) % bq
    pad_k = (-tk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (tq + pad_q) // bq
    nk = (tk + pad_k) // bk

    star = fmt is not None
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(batch, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j, info: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j, info: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j, info: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j, info: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.int32 if star else jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            fmt=fmt,
            causal=causal,
            sliding_window=sliding_window,
            kv_len=tk,
            sm_scale=sm_scale,
            pv_int8=pv_int8,
        ),
        out_shape=jax.ShapeDtypeStruct((batch, hq, tq + pad_q, d), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(info, q, k, v)
    return out[:, :, :tq]
