"""Public wrapper for flash_star: layout handling + defaults.

Accepts the framework-native layout ``q [B, Tq, Hq, D]``, ``k/v
[B, Tk, Hkv, D]`` and returns ``[B, Tq, Hq, D]``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import DEFAULT_FORMAT, FixedPointFormat
from repro.kernels.flash_star.kernel import flash_star_attention


def flash_star_op(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    fmt: Optional[FixedPointFormat] = DEFAULT_FORMAT,  # None = exact softmax
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    kv_valid_len: Optional[jax.Array] = None,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    pv_int8: bool = False,
    interpret: bool = True,
) -> jax.Array:
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    if kv_valid_len is None:
        kv_valid_len = jnp.full((b,), tk, dtype=jnp.int32)
    info = jnp.concatenate(
        [jnp.asarray(q_offset, jnp.int32).reshape(1), kv_valid_len.astype(jnp.int32)]
    )
    qh = jnp.transpose(q, (0, 2, 1, 3))
    kh = jnp.transpose(k, (0, 2, 1, 3))
    vh = jnp.transpose(v, (0, 2, 1, 3))
    out = flash_star_attention(
        qh, kh, vh, info,
        fmt=fmt, causal=causal, sliding_window=sliding_window,
        sm_scale=sm_scale, block_q=block_q, block_k=block_k,
        pv_int8=pv_int8, interpret=interpret,
    )
    return jnp.transpose(out, (0, 2, 1, 3))
