"""Deprecated shim: use ``repro.ops.attention`` with an ``AttentionSpec``.

Kept so pre-dispatch call sites keep working unchanged; it folds the old
kwargs into a spec (``fmt=None`` -> the exact-softmax kind) and dispatches
through the registry.  ``interpret=None`` now means "platform default".

Scheduled for removal: no in-repo caller imports this shim any more
(pinned by ``tests/test_kv_quant.py::test_no_in_repo_shim_importers``);
it exists solely for out-of-tree call sites and will be deleted in a
future PR.  New code must go through ``repro.ops`` directly.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro import ops
from repro.core.fixedpoint import DEFAULT_FORMAT, FixedPointFormat


def flash_star_op(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    fmt: Optional[FixedPointFormat] = DEFAULT_FORMAT,  # None = exact softmax
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    kv_valid_len: Optional[jax.Array] = None,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    pv_int8: bool = False,
    interpret: Optional[bool] = None,
) -> jax.Array:
    from repro.kernels import warn_shim

    warn_shim(
        "repro.kernels.flash_star.ops.flash_star_op",
        "repro.ops.attention with an AttentionSpec(impl='pallas')",
    )
    softmax = (
        ops.SoftmaxSpec(kind="exact")
        if fmt is None
        else ops.SoftmaxSpec(kind="star", precision=fmt)
    )
    spec = ops.AttentionSpec(
        impl="pallas",
        softmax=softmax,
        causal=causal,
        sliding_window=sliding_window,
        block_q=block_q,
        block_k=block_k,
        pv_int8=pv_int8,
        interpret=interpret,
    )
    return ops.attention(
        q, k, v, spec, q_offset=q_offset, kv_valid_len=kv_valid_len, scale=sm_scale
    )
