# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

import warnings

# Shims that have already warned this process (kernels/*/ops.py are
# deprecated adapters onto the repro.ops registry; each warns exactly once
# per process — tests reset this set to re-assert the warning).
_SHIM_WARNED: set = set()


def warn_shim(name: str, replacement: str) -> None:
    """Emit the deprecation warning for shim ``name`` once per process."""
    if name in _SHIM_WARNED:
        return
    _SHIM_WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated: call {replacement} instead "
        "(the shim builds a spec and dispatches through the registry)",
        DeprecationWarning,
        stacklevel=3,
    )
