"""Fused SSD (state-space duality) chunk scan as a Pallas TPU kernel.

Why a kernel: the pure-JAX chunk scan materializes the [Q, Q, H] intra-chunk
decay tensor and the running state in HBM every chunk — the dry-run shows
mamba2's memory term dominating its compute term by >100×.  Fusing one
chunk's intra-quadratic + inter-recurrence in VMEM (state lives in scratch
across the chunk grid) removes that traffic — the same insight as the
paper's vector-grained pipeline, applied to the attention-free mixer.

Grid: ``(batch, num_chunks)`` — chunks innermost so the ``[H, N, P]`` state
scratch carries the recurrence.  Per chunk (Q = chunk length):

  scores  = C Bᵀ                      (Q×Q, MXU)
  decay   = exp(ca_i - ca_j) masked   (VPU, never leaves VMEM)
  y_intra = (scores ⊙ decay_h) @ xdt  (MXU per head)
  y_inter = exp(ca) ⊙ (C @ h_prev)    (MXU)
  h_new   = exp(last) h_prev + Σ_j exp(last - ca_j) B_j xdtᵀ_j
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr, *, nheads: int):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)  # [Q, H, P]
    a = a_ref[0].astype(jnp.float32)  # [Q, H]
    bm = b_ref[0].astype(jnp.float32)  # [Q, N]
    cm = c_ref[0].astype(jnp.float32)  # [Q, N]
    q = x.shape[0]

    ca = jnp.cumsum(a, axis=0)  # [Q, H] inclusive
    last = ca[-1]  # [H]
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, K]
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = rows >= cols

    hprev = h_scr[...]  # [H, N, P]
    # y_inter = exp(ca) * (C @ h_prev)  per head
    y_inter = jnp.einsum("qn,hnp->qhp", cm, hprev)
    y_inter = y_inter * jnp.exp(ca)[:, :, None]

    # y_intra: per head decay-masked score matmul
    decay = jnp.exp(ca[:, None, :] - ca[None, :, :])  # [Q, K, H]
    decay = jnp.where(tri[:, :, None], decay, 0.0)
    y_intra = jnp.einsum("qk,qkh,khp->qhp", scores, decay, x)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    w = jnp.exp(last[None, :] - ca)  # [Q, H] decay from j to chunk end
    s_c = jnp.einsum("qn,qhp,qh->hnp", bm, x, w)
    hnew = hprev * jnp.exp(last)[:, None, None] + s_c
    h_scr[...] = hnew

    @pl.when(ic == nc - 1)
    def _():
        hout_ref[0] = hnew.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    xdt: jax.Array,  # [B, T, H, P]
    a: jax.Array,  # [B, T, H]
    bmat: jax.Array,  # [B, T, N]
    cmat: jax.Array,  # [B, T, N]
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    b, t, h, p = xdt.shape
    n = bmat.shape[-1]
    pad = (-t) % chunk
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = (t + pad) // chunk

    y, hout = pl.pallas_call(
        functools.partial(_kernel, nheads=h),
        out_shape=(
            jax.ShapeDtypeStruct((b, t + pad, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ),
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, h, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, h, n, p), lambda i, j: (i, 0, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((h, n, p), jnp.float32)],
        interpret=interpret,
    )(xdt, a, bmat, cmat)
    return y[:, :t], hout
