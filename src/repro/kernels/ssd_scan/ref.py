"""Pure-jnp oracle for the fused SSD chunk-scan kernel.

The reference is the model's own chunked SSD (`repro.models.ssm`), exposed
here with the kernel's calling convention: per-head inputs, inclusive-cumsum
decay, G=1 (B/C shared across heads).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.ssm import _ssd_chunk_scan


def ssd_scan_ref(
    xdt: jax.Array,  # [B, T, H, P] (x pre-multiplied by dt)
    a: jax.Array,  # [B, T, H] negative log-decay
    bmat: jax.Array,  # [B, T, N]
    cmat: jax.Array,  # [B, T, N]
    chunk: int = 128,
    h0: Optional[jax.Array] = None,  # [B, H, N, P]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,T,H,P], final state [B,H,N,P])."""
    return _ssd_chunk_scan(xdt, a, bmat, cmat, h0, chunk)
