"""Public wrapper for the fused SSD chunk-scan kernel."""

from __future__ import annotations

from typing import Tuple

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas


def ssd_scan_op(
    xdt: jax.Array,
    a: jax.Array,
    bmat: jax.Array,
    cmat: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Fused SSD: (y [B,T,H,P], final state [B,H,N,P])."""
    return ssd_scan_pallas(xdt, a, bmat, cmat, chunk=chunk, interpret=interpret)
