"""Deprecated shim: use ``repro.ops.ssd_scan`` with a ``ScanSpec``.

Kept so pre-dispatch call sites keep working unchanged.  ``interpret=None``
now means "platform default".

Scheduled for removal: no in-repo caller imports this shim any more
(pinned by ``tests/test_kv_quant.py::test_no_in_repo_shim_importers``);
it exists solely for out-of-tree call sites and will be deleted in a
future PR.  New code must go through ``repro.ops`` directly.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro import ops


def ssd_scan_op(
    xdt: jax.Array,
    a: jax.Array,
    bmat: jax.Array,
    cmat: jax.Array,
    *,
    chunk: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused SSD: (y [B,T,H,P], final state [B,H,N,P])."""
    from repro.kernels import warn_shim

    warn_shim(
        "repro.kernels.ssd_scan.ops.ssd_scan_op",
        "repro.ops.ssd_scan with a ScanSpec(impl='pallas')",
    )
    return ops.ssd_scan(
        xdt, a, bmat, cmat, ops.ScanSpec(impl="pallas", chunk=chunk, interpret=interpret)
    )
