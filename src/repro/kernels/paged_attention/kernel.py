"""paged_flash — gather-free paged-attention decode over a block-pool cache.

The gather adapters in ``repro.ops.impls`` re-materialize every slot's
whole KV window (``jnp.take`` over the page pool -> a dense
``[S, W*bs, Hkv, D]`` operand) before the flash kernel ever runs, so paged
decode pays dense-attention HBM traffic *plus* the gather.  This kernel is
the vLLM/TPU lineage answer: the per-slot block table rides in as a
**scalar-prefetch** operand, the grid walks ``(slot, kv_head, kv_block)``,
and each grid step's BlockSpec index map dereferences the table —
``k_pages[tables[s, j]]`` — so the Pallas pipeline DMA-fetches exactly the
one page that step consumes.  No gathered operand exists at any point;
per-token HBM traffic scales with the slot's *live* length, not the pool
width.

Softmax accumulation is the flash_star online form (DESIGN.md §2): on the
STAR path scores snap to the fixed-point grid once, the running max is an
int32 grid index, and both the rescale factor and the probabilities are
codebook (LUT) entries, so the result matches the two-pass engine to
float32 rounding.  ``fmt=None`` runs the exact float32 online softmax.

Layout contract (mirrors ``repro.serve.paged``):

* ``q``          — ``[S, Hq, D]`` one decode token per slot;
* ``k/v_pages``  — ``[N, bs, Hkv, D]`` the flat page pool (block 0 is the
  scratch page: free-slot writes land there, tables of retired slots point
  there);
* ``block_tables`` — ``[S, W]`` int32; logical row ``i`` of slot ``s``
  lives at ``(block_tables[s, i // bs], i % bs)``;
* ``kv_valid``   — ``[S]`` int32 ragged valid prefix per slot.  Ring
  (sliding-window) caches pass ``min(len, cache_t)`` exactly like the
  dense per-slot path — wrap-around changes *where* rows live (the table),
  never the mask, so the ring case needs no kernel support.

Grid ``(S, Hkv, W)`` — KV blocks innermost so the ``(m, l, acc)`` VMEM
scratch carries across a slot's pages; the GQA head group (``Hq // Hkv``
query heads sharing one KV head) forms the row dimension of each score
tile.  Steps whose block lies past ``kv_valid`` are predicated off with
``pl.when`` AND their index map clamps to the slot's last live page, so
consecutive steps request the same block and the Pallas pipeline elides
the redundant DMA — masked tail blocks cost neither MXU work nor HBM
bandwidth.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fixedpoint import GRID_SENTINEL, FixedPointFormat


def _kernel(
    tables_ref,  # int32 [S, W] scalar-prefetch block tables
    valid_ref,  # int32 [S] ragged valid prefix per slot
    *refs,  # quantized: (ks, vs) scale pages lead; then q/k/v/o + scratch
    fmt: Optional[FixedPointFormat],
    bs: int,
    sm_scale: float,
    quantized: bool,
):
    # Operand order past the two index operands:
    #   quantized: ks_ref [N, Hkv], vs_ref [N, Hkv]  (scalar prefetch 3/4)
    #   always:    q_ref (1,1,group,D), k_ref (1,bs,1,D), v_ref (1,bs,1,D),
    #              o_ref (1,1,group,D), m/l/acc scratch
    if quantized:
        ks_ref, vs_ref = refs[0], refs[1]
        refs = refs[2:]
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
    s = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)
    nw = pl.num_programs(2)
    star = fmt is not None
    kv_valid = valid_ref[s]

    @pl.when(j == 0)
    def _init():
        if star:
            m_scr[...] = jnp.full_like(m_scr, GRID_SENTINEL)
        else:
            m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Whole-block skip: every row of page j is past the slot's valid
    # prefix (free slots, table tails).  The index map already pinned the
    # DMA to the last live page, so a skipped step moves no bytes.
    @pl.when(j * bs < kv_valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (group, D)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (bs, D)
        v = v_ref[0, :, 0]
        if quantized:
            # In-kernel dequant (DESIGN.md §13): recompute the clamped page
            # id the index map used for this step's DMA and restore the
            # page's codes through its own (block, head) scale — the same
            # codes * scale expression the gather oracle evaluates, one
            # scalar per grid step.
            last = jnp.maximum((kv_valid + bs - 1) // bs - 1, 0)
            page = tables_ref[s, jnp.minimum(j, last)]
            k = k * ks_ref[page, h]
            v = v.astype(jnp.float32) * vs_ref[page, h]
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (group, bs)

        cols = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        mask = cols < kv_valid  # (1, bs), broadcasts over the head group

        if star:
            nl = fmt.num_levels
            scale_fp = jnp.float32(fmt.scale)
            jgrid = jnp.where(
                mask, jnp.round(sc * scale_fp).astype(jnp.int32), GRID_SENTINEL
            )
            m_blk = jnp.max(jgrid, axis=-1)  # (group,) int32
            m_old = m_scr[...]
            m_new = jnp.maximum(m_old, m_blk)
            shift = jnp.clip(m_new - m_old, 0, nl - 1)
            r = jnp.exp(-shift.astype(jnp.float32) / scale_fp)  # LUT entry
            kidx = jnp.clip(m_new[:, None] - jgrid, 0, nl - 1)
            p = jnp.exp(-kidx.astype(jnp.float32) / scale_fp)  # LUT entries
            p = jnp.where(mask, p, 0.0)
            m_scr[...] = m_new
        else:
            sc = jnp.where(mask, sc, -1e30)
            m_blk = jnp.max(sc, axis=-1)
            m_old = m_scr[...]
            m_new = jnp.maximum(m_old, m_blk)
            r = jnp.exp(m_old - m_new)
            p = jnp.exp(sc - m_new[:, None])
            p = jnp.where(mask, p, 0.0)
            m_scr[...] = m_new

        pv = jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        l_scr[...] = l_scr[...] * r + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * r[:, None] + pv

    @pl.when(j == nw - 1)
    def _finalize():
        den = l_scr[...]
        den = jnp.where(den <= 0.0, 1.0, den)  # free slot: emit zeros
        o_ref[0, 0] = (acc_scr[...] / den[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("fmt", "sm_scale", "interpret")
)
def paged_flash_attention(
    q: jax.Array,  # [S, Hq, D] one decode token per slot
    k_pages: jax.Array,  # [N, bs, Hkv, D] flat page pool
    v_pages: jax.Array,  # [N, bs, Hkv, D]
    block_tables: jax.Array,  # [S, W] int32 page ids
    kv_valid: jax.Array,  # [S] int32 valid prefix per slot
    *,
    fmt: Optional[FixedPointFormat],  # None -> exact online softmax
    sm_scale: Optional[float] = None,
    interpret: bool = True,
    k_scale: Optional[jax.Array] = None,  # [N, Hkv] f32 dequant scales
    v_scale: Optional[jax.Array] = None,  # [N, Hkv] f32
) -> jax.Array:
    """Gather-free paged decode attention.  Returns ``[S, Hq, D]``.

    With ``k_scale``/``v_scale`` the pages hold quantized codes
    (``core.kvquant`` — int8 or fp8_e4m3): the scale pages ride the
    scalar-prefetch path next to the block tables and each grid step
    dequantizes its one page in VMEM, so the ``[S, W*bs, Hkv, D]``
    gathered operand never exists at *any* precision (DESIGN.md §13).
    """
    s, hq, d = q.shape
    n, bs, hkv, _ = k_pages.shape
    assert hq % hkv == 0, "GQA needs Hq % Hkv == 0"
    if (k_scale is None) != (v_scale is None):
        raise ValueError("pass both k_scale and v_scale, or neither")
    quantized = k_scale is not None
    group = hq // hkv
    w = block_tables.shape[1]
    sm_scale = (d ** -0.5) if sm_scale is None else sm_scale

    # Head h of q attends through KV head h // group (the flash_star
    # convention), so the contiguous reshape groups exactly right.
    qg = q.reshape(s, hkv, group, d)
    tables = block_tables.astype(jnp.int32)
    valid = kv_valid.astype(jnp.int32)

    def q_map(si, hi, ji, tables, valid, *scales):
        del ji, tables, valid, scales
        return (si, hi, 0, 0)

    def kv_map(si, hi, ji, tables, valid, *scales):
        # Clamp table lookups past the valid prefix to the slot's last
        # live page: consecutive masked steps then request the *same*
        # block, and the pipeline elides the DMA.  An all-free slot
        # (valid == 0) pins to table column 0 — the scratch page.
        del scales
        last = jnp.maximum((valid[si] + bs - 1) // bs - 1, 0)
        return (tables[si, jnp.minimum(ji, last)], 0, hi, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4 if quantized else 2,
        grid=(s, hkv, w),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), q_map),
            pl.BlockSpec((1, bs, 1, d), kv_map),
            pl.BlockSpec((1, bs, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.int32 if fmt is not None else jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    call = pl.pallas_call(
        functools.partial(
            _kernel, fmt=fmt, bs=bs, sm_scale=sm_scale, quantized=quantized
        ),
        out_shape=jax.ShapeDtypeStruct((s, hkv, group, d), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )
    if quantized:
        out = call(
            tables, valid,
            k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
            qg, k_pages, v_pages,
        )
    else:
        out = call(tables, valid, qg, k_pages, v_pages)
    return out.reshape(s, hq, d)
