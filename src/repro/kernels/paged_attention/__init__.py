"""Gather-free paged-attention decode kernel (DESIGN.md §11).

The kernel consumes the block-pool KV layout *in place*: per-slot block
tables arrive as scalar-prefetch operands and the grid's index maps
dereference them, so no gathered ``[S, W*bs, Hkv, D]`` operand is ever
materialized.  Registered as the ``("paged_attention", "pallas_paged")``
backend in ``repro.ops.impls``.
"""

from repro.kernels.paged_attention.kernel import paged_flash_attention  # noqa: F401
