"""Platform detection: one place decides how Pallas kernels execute.

Every ``kernels/*/ops.py`` wrapper used to hardcode ``interpret: bool =
True`` ("this container is CPU-only") and rely on callers to flip it on
real hardware.  ``default_interpret()`` replaces all of that: Pallas
kernels compile natively when a TPU is attached and fall back to interpret
mode everywhere else — callers (including the launchers) never touch the
flag unless they explicitly want to override it via a spec or
``ops.use(interpret=...)``.

``REPRO_OPS_INTERPRET=0|1`` force-overrides detection (escape hatch for
debugging a miscompiled kernel on TPU, or timing compiled CPU lowering).
"""

from __future__ import annotations

import functools
import os
from typing import Optional


@functools.lru_cache(maxsize=None)
def detected_platform() -> str:
    """The JAX default backend platform: ``cpu`` | ``gpu`` | ``tpu``."""
    import jax

    return jax.default_backend()


def default_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode here."""
    env = os.environ.get("REPRO_OPS_INTERPRET")
    if env is not None and env != "":
        return env.lower() not in ("0", "false", "no")
    return detected_platform() != "tpu"


def resolve_interpret(flag: Optional[bool]) -> bool:
    """Resolve a spec's tri-state interpret field (None -> platform)."""
    return default_interpret() if flag is None else flag
