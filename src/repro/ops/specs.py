"""Frozen, hashable op specs — the contract half of the dispatch layer.

A spec fully describes *what* to compute (softmax kind/mode/precision,
attention masking and blocking, crossbar matmul quantization) and *which*
backend family computes it (``impl``).  Specs are frozen dataclasses so they
hash and compare by value: they are safe jit cache keys (``static_argnames``)
and safe dict keys for the registry.

Precision is either a :class:`~repro.core.fixedpoint.FixedPointFormat`, a
named policy string ``"auto:<dataset>"`` resolved through
``repro.core.precision.policy_for`` (the paper's per-dataset calibration),
or irrelevant when ``kind == "exact"`` (the FP oracle).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Union

from repro.core.fixedpoint import DEFAULT_FORMAT, FixedPointFormat
from repro.core.kvquant import KV_DTYPES
from repro.core.precision import policy_for
from repro.hwmodel.faults import FaultModel
from repro.kernels.crossbar_matmul.ref import DEFAULT_SPEC, CrossbarSpec

SOFTMAX_KINDS = ("star", "star_ste", "exact")
SOFTMAX_MODES = ("gather", "onehot", "histogram")

Precision = Union[FixedPointFormat, str]


def resolve_precision(precision: Precision) -> FixedPointFormat:
    """Resolve a precision field to a concrete fixed-point format.

    Accepts a :class:`FixedPointFormat` (returned as-is) or a named policy
    ``"auto:<dataset>"`` (e.g. ``"auto:mrpc"``) resolved via the paper's
    calibrated per-dataset table in ``core.precision``.
    """
    if isinstance(precision, FixedPointFormat):
        return precision
    if isinstance(precision, str):
        if precision.startswith("auto:"):
            return policy_for(precision.split(":", 1)[1])
        raise ValueError(
            f"unknown precision policy {precision!r}: expected a "
            f"FixedPointFormat or an 'auto:<dataset>' policy name "
            f"(datasets: cnews, mrpc, cola; anything else falls back to "
            f"the default {DEFAULT_FORMAT.short_name()} format)"
        )
    raise TypeError(
        f"precision must be a FixedPointFormat or 'auto:<dataset>' string, "
        f"got {type(precision).__name__}"
    )


@dataclasses.dataclass(frozen=True)
class SoftmaxSpec:
    """One softmax invocation: engine kind, dataflow mode, precision, impl.

    ``impl``: ``"reference"`` (pure-jnp engine, ``core.star_softmax``),
    ``"xla"`` (``jax.nn.softmax`` — exact kind only), ``"pallas"`` (the
    fused TPU kernel, ``kernels.star_softmax``).

    ``interpret=None`` means "ask the platform": Pallas kernels run in
    interpret mode unless a TPU is attached (``ops.platform``).
    """

    impl: str = "reference"
    kind: str = "star"  # star | star_ste | exact
    mode: str = "gather"  # gather | onehot | histogram
    precision: Precision = DEFAULT_FORMAT
    block_rows: int = 8  # pallas: row tile
    interpret: Optional[bool] = None  # None -> platform default
    # Seeded device non-idealities (DESIGN.md §9).  None = ideal device; a
    # null (all-zero) model normalizes to None so it cannot split jit
    # caches or spec equality.
    fault: Optional[FaultModel] = None

    op = "softmax"

    def __post_init__(self) -> None:
        if self.kind not in SOFTMAX_KINDS:
            raise ValueError(
                f"softmax kind must be one of {SOFTMAX_KINDS}, got {self.kind!r}"
            )
        if self.mode not in SOFTMAX_MODES:
            raise ValueError(
                f"softmax mode must be one of {SOFTMAX_MODES}, got {self.mode!r}"
            )
        if self.fault is not None and self.fault.is_null:
            object.__setattr__(self, "fault", None)
        if self.fault is not None and self.kind == "exact":
            raise ValueError(
                "kind='exact' is the digital FP oracle — there is no RRAM "
                "array to inject faults into; use kind='star' (or drop the "
                "fault field)"
            )
        resolve_precision(self.precision)  # fail early on bad policies

    @property
    def fmt(self) -> Optional[FixedPointFormat]:
        """Resolved fixed-point format; ``None`` for the exact oracle."""
        if self.kind == "exact":
            return None
        return resolve_precision(self.precision)

    def tolerance(self) -> float:
        """Provable max-abs-error bound vs the exact softmax oracle.

        Rounding to the grid moves each logit by at most ``r/2``
        (``r = 2^-frac_bits``), so every probability ratio is within
        ``e^r`` of exact: ``|p_hat - p| <= e^r - 1``.  Exact kinds get a
        float32 roundoff allowance.

        The bound assumes an ideal device: injected faults can push error
        past it — which is exactly the contract the accuracy guard
        (``repro.ops.guard``) enforces at dispatch time.
        """
        fmt = self.fmt
        if fmt is None:
            return 1e-6
        return math.exp(fmt.resolution) - 1.0


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """One attention invocation: masking, blocking, and the softmax engine.

    ``impl``: ``"reference"`` (whole-operand, scores materialized),
    ``"xla"`` (online-blocked ``lax.scan`` pipeline; falls back to the
    materialized path for short rows and single-token decode), ``"pallas"``
    (the fused ``flash_star`` kernel).

    ``ragged=True`` declares that calls will pass per-batch
    ``kv_valid_len`` vectors (continuous-batching slot pools).

    ``fault`` is sugar for ``softmax=replace(softmax, fault=...)``: the
    attention engine's RRAM arrays live in its softmax stage, so the model
    folds into the nested spec (and wins over a fault already set there).
    """

    impl: str = "xla"
    softmax: SoftmaxSpec = SoftmaxSpec()
    causal: bool = False
    sliding_window: Optional[int] = None
    ragged: bool = False
    block_q: int = 128  # pallas: query tile
    block_k: int = 128  # pallas: KV tile
    block_kv: int = 512  # xla: scan block
    pv_int8: bool = False  # pallas: int8 P.V MXU path
    interpret: Optional[bool] = None
    fault: Optional[FaultModel] = None  # folds into .softmax (see above)

    op = "attention"

    def __post_init__(self) -> None:
        if self.sliding_window is not None and self.sliding_window <= 0:
            raise ValueError(f"sliding_window must be > 0, got {self.sliding_window}")
        for field in ("block_q", "block_k", "block_kv"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be > 0, got {getattr(self, field)}")
        if self.fault is not None and self.fault.is_null:
            object.__setattr__(self, "fault", None)
        if self.fault is not None:
            object.__setattr__(
                self, "softmax", dataclasses.replace(self.softmax, fault=self.fault)
            )


@dataclasses.dataclass(frozen=True)
class PagedAttentionSpec:
    """One paged-attention decode invocation over a block-pool KV cache.

    The operands are a KV *page pool* (``[num_blocks, block_size, Hkv, D]``)
    plus per-sequence block tables (``[S, W]`` int32 — see
    ``repro.serve.paged``): every backend gathers each sequence's blocks
    through its table and decodes over the ragged per-sequence valid
    lengths.  Masked softmax makes the result identical to the dense
    per-slot path, which is what the serve parity suite asserts.

    ``impl``: ``"reference"`` (gather + whole-operand attention),
    ``"xla"`` (gather via ``jnp.take`` + the online-blocked dense
    pipeline), ``"pallas"`` (gather + the fused ``flash_star`` kernel with
    the ragged-length info vector).

    ``block_size`` is the declared tokens-per-block default; backends
    trust the runtime page shape, the field exists so the spec fully
    records the configuration (benchmark emission, jit cache keys).

    ``kv_dtype`` declares the page-pool storage layout (DESIGN.md §13):
    ``"fp32"`` stores values directly; ``"int8"`` / ``"fp8_e4m3"`` store
    codes plus per-(block, head) scale pages that every call must supply
    via ``kv_scales``.  Gather backends dequantize the gathered codes (the
    oracle the kernel is parity-tested against); ``pallas_paged``
    dequantizes inside the kernel with the scales riding scalar prefetch.
    """

    impl: str = "xla"
    softmax: SoftmaxSpec = SoftmaxSpec()
    block_size: int = 16  # tokens per KV block
    block_q: int = 128  # pallas: query tile
    block_k: int = 128  # pallas: KV tile
    kv_dtype: str = "fp32"  # fp32 | int8 | fp8_e4m3 (core.kvquant)
    interpret: Optional[bool] = None

    op = "paged_attention"

    def __post_init__(self) -> None:
        for field in ("block_size", "block_q", "block_k"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be > 0, got {getattr(self, field)}")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {KV_DTYPES}, got {self.kv_dtype!r}"
            )


@dataclasses.dataclass(frozen=True)
class MatmulSpec:
    """One matmul invocation.

    ``impl``: ``"xla"`` (native MXU — the performance path) or
    ``"hwmodel"`` (the RRAM crossbar behavioural model: 8-bit operands on
    128x128 tiles through a 5-bit ADC — the paper-table accuracy oracle).
    """

    impl: str = "xla"
    crossbar: CrossbarSpec = DEFAULT_SPEC
    ranging: str = "calibrated"  # hwmodel ADC ranging: calibrated | fullscale
    block_m: int = 128
    interpret: Optional[bool] = None
    fault: Optional[FaultModel] = None  # crossbar cell / ADC faults (§9)

    op = "matmul"

    def __post_init__(self) -> None:
        if self.ranging not in ("calibrated", "fullscale"):
            raise ValueError(
                f"ranging must be 'calibrated' or 'fullscale', got {self.ranging!r}"
            )
        if self.fault is not None and self.fault.is_null:
            object.__setattr__(self, "fault", None)


@dataclasses.dataclass(frozen=True)
class ScanSpec:
    """One fused SSD chunk-scan invocation (mamba2 mixer).

    Not part of the paper's softmax engine, but registered through the same
    dispatch layer so the interpret-flag and backend-sweep machinery covers
    every Pallas kernel in the repo.
    """

    impl: str = "pallas"
    chunk: int = 128
    interpret: Optional[bool] = None

    op = "ssd_scan"

    def __post_init__(self) -> None:
        if self.chunk <= 0:
            raise ValueError(f"chunk must be > 0, got {self.chunk}")


Spec = Union[SoftmaxSpec, AttentionSpec, PagedAttentionSpec, MatmulSpec, ScanSpec]


def spec_json(spec: Spec) -> Dict[str, Any]:
    """JSON-serializable dict of a spec (benchmark emission, logging)."""
    out: Dict[str, Any] = {"op": spec.op}
    for f in dataclasses.fields(spec):
        v = getattr(spec, f.name)
        if dataclasses.is_dataclass(v) and not isinstance(v, type):
            v = dataclasses.asdict(v)
        elif isinstance(v, tuple):
            v = list(v)
        out[f.name] = v
    return out
