"""Dispatch: spec -> (overrides, platform resolution, validation) -> backend.

The resolution order, outermost-wins:

1. the spec itself (or the op's default spec when ``spec=None``);
2. call-site keyword overrides (any spec field, e.g. ``causal=True`` or
   ``impl="pallas"``), applied via ``dataclasses.replace``;
3. active :func:`repro.ops.use` frames (impl / interpret retargeting —
   inner frames win over outer, and over the spec: that is their purpose);
4. ``interpret=None`` resolves to the detected platform's default.

The resolved spec is capability-validated against the selected backend
before the call, so mismatches fail with an actionable error naming the
field, the backend's supported values, and the impls that do support it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax

from repro.obs.metrics import default_registry as _obs_registry
from repro.ops import registry
from repro.ops.guard import Guard, as_guard
from repro.ops.platform import resolve_interpret
from repro.ops.registry import Backend, OpDispatchError
from repro.ops.specs import (
    AttentionSpec,
    MatmulSpec,
    PagedAttentionSpec,
    ScanSpec,
    SoftmaxSpec,
    Spec,
)

DEFAULT_SOFTMAX = SoftmaxSpec()
DEFAULT_ATTENTION = AttentionSpec()
DEFAULT_PAGED_ATTENTION = PagedAttentionSpec()
DEFAULT_MATMUL = MatmulSpec()
DEFAULT_SSD_SCAN = ScanSpec()


def resolve(spec: Spec, **overrides: Any) -> Tuple[Backend, Spec]:
    """Apply overrides and ``use()`` frames, pick and validate the backend."""
    if overrides:
        try:
            spec = dataclasses.replace(spec, **overrides)
        except TypeError as exc:
            fields = [f.name for f in dataclasses.fields(spec)]
            raise OpDispatchError(
                f"invalid {type(spec).__name__} override(s) "
                f"{sorted(overrides)}: valid fields are {fields}"
            ) from exc
    ctx = registry.active_overrides(spec.op)
    updates: dict = {}
    if "impl" in ctx:
        updates["impl"] = ctx["impl"]
    updates["interpret"] = resolve_interpret(ctx.get("interpret", spec.interpret))
    spec = dataclasses.replace(spec, **updates)
    backend = registry.get(spec.op, spec.impl)
    registry.validate(backend, spec)
    # per-(op, resolved impl) dispatch counter (DESIGN.md §10).  Counts
    # *dispatches*: for a jitted call site that is trace time, so a cached
    # retrace-free loop counts once — which is itself a useful signal.
    _obs_registry().counter("ops.dispatch.calls").inc(
        op=spec.op, impl=backend.impl
    )
    return backend, spec


def validate(spec: Spec, **overrides: Any) -> Spec:
    """Resolve + capability-check a spec without executing anything.

    Launchers call this at config time so a spec the registry cannot serve
    fails before any lowering starts.  Returns the resolved spec.
    """
    return resolve(spec, **overrides)[1]


def softmax(
    x: jax.Array,
    spec: Optional[SoftmaxSpec] = None,
    *,
    where: Optional[jax.Array] = None,
    axis: int = -1,
    guard: Optional[Guard] = None,
    **overrides: Any,
) -> jax.Array:
    """Softmax over ``axis`` through the registered backend for ``spec``.

    ``guard`` (an :class:`~repro.ops.guard.AccuracyGuard` or
    :class:`~repro.ops.guard.GuardConfig`) wraps the call in the accuracy
    guard: sampled comparison against the exact oracle, fallback to a clean
    backend on tolerance violation.  Eager call sites only.
    """
    backend, spec = resolve(spec if spec is not None else DEFAULT_SOFTMAX, **overrides)
    g = as_guard(guard)
    if g is not None:
        return g.softmax(backend, spec, x, where=where, axis=axis)
    return backend.fn(spec, x, where=where, axis=axis)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    spec: Optional[AttentionSpec] = None,
    *,
    q_offset: Any = 0,
    kv_valid_len: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    **overrides: Any,
) -> jax.Array:
    """Attention (q [B,Tq,Hq,D], k/v [B,Tk,Hkv,D]) -> [B,Tq,Hq,D]."""
    backend, spec = resolve(
        spec if spec is not None else DEFAULT_ATTENTION, **overrides
    )
    return backend.fn(
        spec, q, k, v, q_offset=q_offset, kv_valid_len=kv_valid_len, scale=scale
    )


def paged_attention(
    q: jax.Array,  # [S, Tq, Hq, D] (decode: Tq == 1)
    k_pages: jax.Array,  # [num_blocks, block_size, Hkv, D]
    v_pages: jax.Array,  # [num_blocks, block_size, Hkv, D]
    block_tables: jax.Array,  # [S, W] int32 block ids per sequence
    spec: Optional[PagedAttentionSpec] = None,
    *,
    kv_valid_len: jax.Array,  # [S] ragged valid prefix per sequence
    kv_len: Optional[int] = None,  # logical gathered length (<= W * block_size)
    scale: Optional[float] = None,
    kv_scales: Optional[Tuple[jax.Array, jax.Array]] = None,  # ([N,H], [N,H])
    **overrides: Any,
) -> jax.Array:
    """Paged-KV decode attention: gather each sequence's blocks through its
    table, attend over the ragged valid prefix.  Returns ``[S, Tq, Hq, D]``.

    ``kv_len`` trims the gathered buffer to the logical cache length when
    the block grid overshoots it (``W * block_size`` rows gathered, only
    ``kv_len`` meaningful) so the operands — and hence the numerics — match
    the dense per-slot cache exactly.

    ``kv_scales`` carries the per-(block, head) dequant scale pages
    ``(k_scale, v_scale)`` when the pool stores quantized codes
    (``spec.kv_dtype != "fp32"`` — DESIGN.md §13); required then,
    forbidden otherwise, so a layout/spec mismatch fails loudly here
    instead of decoding garbage.
    """
    backend, spec = resolve(
        spec if spec is not None else DEFAULT_PAGED_ATTENTION, **overrides
    )
    if (spec.kv_dtype != "fp32") != (kv_scales is not None):
        raise OpDispatchError(
            f"kv_dtype={spec.kv_dtype!r} but kv_scales "
            f"{'missing' if kv_scales is None else 'supplied'}: quantized "
            "page pools must pass their (k_scale, v_scale) pages and fp32 "
            "pools must not (DESIGN.md §13)"
        )
    return backend.fn(
        spec,
        q,
        k_pages,
        v_pages,
        block_tables,
        kv_valid_len=kv_valid_len,
        kv_len=kv_len,
        scale=scale,
        kv_scales=kv_scales,
    )


def matmul(
    x: jax.Array,
    w: jax.Array,
    spec: Optional[MatmulSpec] = None,
    *,
    guard: Optional[Guard] = None,
    **overrides: Any,
) -> jax.Array:
    """x [M, K] @ w [K, N] through the registered backend for ``spec``.

    ``guard`` as in :func:`softmax` (matmul uses a relative max-abs error
    metric against the exact f32 product).
    """
    backend, spec = resolve(spec if spec is not None else DEFAULT_MATMUL, **overrides)
    g = as_guard(guard)
    if g is not None:
        return g.matmul(backend, spec, x, w)
    return backend.fn(spec, x, w)


def ssd_scan(
    xdt: jax.Array,
    a: jax.Array,
    bmat: jax.Array,
    cmat: jax.Array,
    spec: Optional[ScanSpec] = None,
    **overrides: Any,
):
    """Fused SSD chunk scan: (y [B,T,H,P], final state [B,H,N,P])."""
    backend, spec = resolve(spec if spec is not None else DEFAULT_SSD_SCAN, **overrides)
    return backend.fn(spec, xdt, a, bmat, cmat)
