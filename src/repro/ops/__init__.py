"""``repro.ops`` — the unified op dispatch layer (DESIGN.md §7).

One softmax *contract*, many implementations: frozen hashable specs
(:class:`SoftmaxSpec` / :class:`AttentionSpec` / :class:`MatmulSpec` /
:class:`ScanSpec`) describe an invocation; a capability-checked registry
maps ``(op, impl)`` to a backend; :func:`softmax` / :func:`attention` /
:func:`matmul` / :func:`ssd_scan` dispatch through it.

    from repro import ops

    probs = ops.softmax(x, ops.SoftmaxSpec(precision="auto:mrpc"))
    out = ops.attention(q, k, v, impl="pallas", causal=True)
    with ops.use(softmax="reference", interpret=True):
        ...  # retarget every dispatch in the block (tests / benchmarks)

New backends, precision policies, and hardware targets are registry
entries (:func:`register`), not cross-cutting edits.
"""

from repro.ops.dispatch import (  # noqa: F401
    DEFAULT_ATTENTION,
    DEFAULT_MATMUL,
    DEFAULT_PAGED_ATTENTION,
    DEFAULT_SOFTMAX,
    DEFAULT_SSD_SCAN,
    attention,
    matmul,
    paged_attention,
    resolve,
    softmax,
    ssd_scan,
    validate,
)
from repro.hwmodel.faults import FaultModel  # noqa: F401
from repro.ops.guard import (  # noqa: F401
    AccuracyGuard,
    GuardConfig,
    GuardTripWarning,
)
from repro.ops.platform import (  # noqa: F401
    default_interpret,
    detected_platform,
    resolve_interpret,
)
from repro.ops.registry import (  # noqa: F401
    Backend,
    CapabilityError,
    OpDispatchError,
    UnknownBackendError,
    backends,
    get,
    register,
    registered_ops,
    unregister,
    use,
)
from repro.ops.specs import (  # noqa: F401
    AttentionSpec,
    MatmulSpec,
    PagedAttentionSpec,
    ScanSpec,
    SoftmaxSpec,
    Spec,
    resolve_precision,
    spec_json,
)

# Importing the built-in backends populates the registry as a side effect.
from repro.ops import impls as _impls  # noqa: E402,F401  isort: skip
from repro.ops.impls import paged_gather_bytes  # noqa: E402,F401  isort: skip
