"""Accuracy-guarded dispatch: compare against the exact oracle, fall back.

The fault layer (DESIGN.md §9) makes degraded hardware expressible; this
module makes it *survivable*.  An :class:`AccuracyGuard` attached to a
dispatch call (``ops.softmax(x, spec, guard=g)``) re-runs a sampled
fraction of calls through the exact reference oracle and, when the
observed error exceeds the spec's tolerance contract, emits a structured
:class:`GuardTripWarning` and re-dispatches the call on a *clean* backend
(fault stripped, ``fallback_impl``).  Counters (calls / checks / trips /
fallbacks / last error) live on the guard instance, and the serving engine
surfaces them in ``ContinuousBatchingEngine.stats()`` — a production knob:
a drifting RRAM part degrades to the digital path instead of silently
serving garbage.

The guard is a *host-side* mechanism: it needs concrete arrays to measure
error against the oracle.  Inside ``jit``/``vmap`` tracing the comparison
is impossible, so guarded dispatch raises an actionable error rather than
silently not checking — guard at the eager serving layer (sampling,
admission) and let jitted inner loops run unguarded.

Latching: after the first trip the guard routes every subsequent guarded
call straight to the clean backend (``latch=True``, the default) — the
graceful-degradation mode.  ``latch=False`` keeps probing the faulty
backend, which is what accuracy sweeps want.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.obs import get_tracer
from repro.obs.metrics import default_registry as _obs_registry
from repro.ops import registry
from repro.ops.registry import OpDispatchError


class GuardTripWarning(UserWarning):
    """A guarded dispatch exceeded its tolerance and fell back.

    Structured: ``op``, ``impl``, ``error``, ``tolerance``, and
    ``fallback_impl`` are attributes, not just message text.
    """

    def __init__(
        self, op: str, impl: str, error: float, tolerance: float, fallback_impl: str
    ):
        self.op = op
        self.impl = impl
        self.error = error
        self.tolerance = tolerance
        self.fallback_impl = fallback_impl
        super().__init__(
            f"{op} backend {impl!r} exceeded its accuracy contract "
            f"(error {error:.3e} > tolerance {tolerance:.3e}); falling "
            f"back to the clean {fallback_impl!r} backend"
        )


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Policy half of the guard (frozen; counters live on AccuracyGuard).

    ``sample_every``: check every Nth guarded call against the oracle
    (1 = every call).  Deterministic counter-based sampling — no RNG, so
    a replayed trace checks the same calls.
    ``tolerance``: override the error budget; ``None`` uses the spec's own
    contract (``SoftmaxSpec.tolerance()``) for softmax and
    ``matmul_rtol`` (relative max-abs) for matmul.
    ``fallback_impl``: backend the guard re-dispatches to, with the fault
    stripped from the spec; ``None`` picks the op's clean default
    (``"reference"`` for softmax, ``"xla"`` for matmul).
    ``latch``: once tripped, stop dispatching the degraded backend at all.
    """

    sample_every: int = 1
    tolerance: Optional[float] = None
    fallback_impl: Optional[str] = None
    latch: bool = True
    matmul_rtol: float = 0.05

    def __post_init__(self) -> None:
        if self.sample_every < 1:
            raise ValueError(
                f"sample_every must be >= 1, got {self.sample_every}"
            )
        if self.tolerance is not None and self.tolerance <= 0.0:
            raise ValueError(f"tolerance must be > 0, got {self.tolerance}")


def clean_spec(spec, impl: str):
    """Degradation-free twin of ``spec`` on backend ``impl``.

    The guard's fallback must not re-trip on the very degradation it is
    escaping, so every accuracy-reducing field the spec family carries is
    stripped by introspection: the fault model (``fault=None``) and
    quantized KV storage (``kv_dtype="fp32"``).  Fields a given spec type
    lacks are simply skipped, so one helper serves softmax, matmul, and
    any future guarded op.
    """
    updates: dict = {"impl": impl}
    names = {f.name for f in dataclasses.fields(spec)}
    if "fault" in names:
        updates["fault"] = None
    if "kv_dtype" in names:
        updates["kv_dtype"] = "fp32"
    return dataclasses.replace(spec, **updates)


class AccuracyGuard:
    """Stateful guard: counters + trip latch.  Reuse one instance across
    calls — a fresh guard per call cannot accumulate stats or latch."""

    def __init__(self, config: GuardConfig = GuardConfig()):
        self.config = config
        self.calls = 0  # guarded dispatches seen
        self.checks = 0  # oracle comparisons actually run
        self.trips = 0  # tolerance violations observed
        self.fallbacks = 0  # calls served by the clean backend
        self.tripped = False  # latch state
        self.last_error: Optional[float] = None

    def stats(self) -> Dict[str, Any]:
        return {
            "calls": self.calls,
            "checks": self.checks,
            "trips": self.trips,
            "fallbacks": self.fallbacks,
            "tripped": self.tripped,
            "last_error": self.last_error,
        }

    # -- internals -----------------------------------------------------------

    def _should_check(self) -> bool:
        return (self.calls - 1) % self.config.sample_every == 0

    def _fallback_impl(self, op: str) -> str:
        if self.config.fallback_impl is not None:
            return self.config.fallback_impl
        return "reference" if op == "softmax" else "xla"

    @staticmethod
    def _require_concrete(x: jax.Array, op: str) -> None:
        if isinstance(x, jax.core.Tracer):
            raise OpDispatchError(
                f"guarded ops.{op} was called under jit/vmap tracing: the "
                "accuracy guard compares concrete outputs against the exact "
                "oracle on the host.  Guard eager call sites (e.g. the "
                "serving layer's sampling path) and leave traced inner "
                "loops unguarded."
            )

    # Every instance counter mirrors into the process-global obs registry
    # (labeled by op) and trips land in the active trace (DESIGN.md §10):
    # a guard fallback is visible in an exported Perfetto trace and in
    # metrics snapshots, not only as a Python warning.

    @staticmethod
    def _note(event: str, op: str) -> None:
        _obs_registry().counter(f"ops.guard.{event}").inc(op=op)

    def _trip(self, op: str, impl: str, err: float, tol: float) -> None:
        self.trips += 1
        self.tripped = True
        fallback = self._fallback_impl(op)
        _obs_registry().counter("ops.guard.trips").inc(op=op, impl=impl)
        tracer = get_tracer()
        if tracer.enabled:
            tracer.instant(
                "guard.trip", cat="guard", op=op, impl=impl, error=err,
                tolerance=tol, fallback=fallback,
            )
        warnings.warn(
            GuardTripWarning(op, impl, err, tol, fallback),
            stacklevel=4,
        )

    # -- guarded ops ---------------------------------------------------------

    def softmax(self, backend, spec, x, *, where=None, axis=-1):
        """Guarded softmax dispatch (called by ``repro.ops.dispatch``)."""
        self._require_concrete(x, "softmax")
        cfg = self.config
        fb = self._fallback_impl("softmax")
        clean = clean_spec(spec, fb)
        clean_fn = registry.get("softmax", fb).fn
        if self.tripped and cfg.latch:
            self.calls += 1
            self.fallbacks += 1
            self._note("calls", "softmax")
            self._note("fallbacks", "softmax")
            return clean_fn(clean, x, where=where, axis=axis)
        out = backend.fn(spec, x, where=where, axis=axis)
        self.calls += 1
        self._note("calls", "softmax")
        if not self._should_check():
            return out
        self.checks += 1
        self._note("checks", "softmax")
        exact = dataclasses.replace(
            clean, kind="exact", precision=spec.precision
        )
        ref = registry.get("softmax", fb).fn(exact, x, where=where, axis=axis)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref)))
        self.last_error = err
        tol = cfg.tolerance if cfg.tolerance is not None else spec.tolerance()
        if err > tol:
            self._trip("softmax", spec.impl, err, tol)
            self.fallbacks += 1
            self._note("fallbacks", "softmax")
            return clean_fn(clean, x, where=where, axis=axis)
        return out

    def matmul(self, backend, spec, x, w):
        """Guarded matmul dispatch: relative max-abs error vs exact."""
        self._require_concrete(x, "matmul")
        cfg = self.config
        fb = self._fallback_impl("matmul")
        clean = clean_spec(spec, fb)
        clean_fn = registry.get("matmul", fb).fn
        if self.tripped and cfg.latch:
            self.calls += 1
            self.fallbacks += 1
            self._note("calls", "matmul")
            self._note("fallbacks", "matmul")
            return clean_fn(clean, x, w)
        out = backend.fn(spec, x, w)
        self.calls += 1
        self._note("calls", "matmul")
        if not self._should_check():
            return out
        self.checks += 1
        self._note("checks", "matmul")
        ref = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
        denom = float(jnp.max(jnp.abs(ref))) or 1.0
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref))) / denom
        self.last_error = err
        tol = cfg.tolerance if cfg.tolerance is not None else cfg.matmul_rtol
        if err > tol:
            self._trip("matmul", spec.impl, err, tol)
            self.fallbacks += 1
            self._note("fallbacks", "matmul")
            return clean_fn(clean, x, w)
        return out


Guard = Union[AccuracyGuard, GuardConfig]


def as_guard(guard: Optional[Guard]) -> Optional[AccuracyGuard]:
    """Normalize the dispatch-level ``guard=`` argument.

    Accepts an :class:`AccuracyGuard` (reused — counters accumulate), a
    :class:`GuardConfig` (wrapped fresh: convenient but stateless across
    calls), or ``None``.
    """
    if guard is None or isinstance(guard, AccuracyGuard):
        return guard
    if isinstance(guard, GuardConfig):
        return AccuracyGuard(guard)
    raise OpDispatchError(
        f"guard must be an AccuracyGuard, GuardConfig, or None; got "
        f"{type(guard).__name__}"
    )
