"""Built-in backends: the registry entries shipped with the repo.

Importing this module (done by ``repro.ops``) registers every built-in
implementation.  Each backend is a thin adapter from the spec contract to
an existing engine — the pure-jnp oracles in ``repro.core``, plain XLA
ops, the Pallas kernels in ``repro.kernels``, or the RRAM behavioural
model.  Numerics live in those modules; this file only routes.

Adding a backend is one call::

    from repro.ops import register

    register(
        "softmax", "my_impl", my_fn,
        capabilities={"kind": ("star",), "mode": ("gather", "histogram")},
        description="...",
    )

where ``my_fn(spec, x, *, where, axis)`` receives the resolved
:class:`~repro.ops.specs.SoftmaxSpec` plus the runtime arrays.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import kvquant
from repro.core.attention import (
    NEG_INF,
    SoftmaxConfig,
    attention as full_attention,
    blocked_attention,
)
from repro.core.star_softmax import exact_softmax, star_softmax, star_softmax_ste
from repro.hwmodel import faults as faults_lib
from repro.kernels.crossbar_matmul.kernel import crossbar_matmul_pallas
from repro.kernels.crossbar_matmul.ref import (
    _pad_to,
    adc_step,
    apply_weight_faults,
    quantize_operands,
)
from repro.kernels.flash_star.kernel import flash_star_attention
from repro.kernels.paged_attention.kernel import paged_flash_attention
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.star_softmax.kernel import star_softmax_pallas
from repro.ops.registry import CapabilityError, register
from repro.ops.specs import (
    AttentionSpec,
    MatmulSpec,
    PagedAttentionSpec,
    ScanSpec,
    SoftmaxSpec,
)

# ---------------------------------------------------------------------------
# softmax


def _softmax_reference(
    spec: SoftmaxSpec,
    x: jax.Array,
    *,
    where: Optional[jax.Array] = None,
    axis: int = -1,
) -> jax.Array:
    if spec.kind == "exact":
        if where is not None:
            x = jnp.where(where, x, NEG_INF)
        return exact_softmax(x, axis=axis)
    if spec.kind == "star_ste":
        if where is not None:
            # NEG_INF quantizes to the deepest LUT row (probability ~ 0).
            x = jnp.where(where, x, NEG_INF)
        return star_softmax_ste(x, spec.fmt, axis, spec.mode, spec.fault)
    return star_softmax(
        x, spec.fmt, axis=axis, mode=spec.mode, where=where, fault=spec.fault
    )


def _softmax_xla(
    spec: SoftmaxSpec,
    x: jax.Array,
    *,
    where: Optional[jax.Array] = None,
    axis: int = -1,
) -> jax.Array:
    if where is not None:
        x = jnp.where(where, x, NEG_INF)
    return jax.nn.softmax(x, axis=axis)


def _softmax_pallas(
    spec: SoftmaxSpec,
    x: jax.Array,
    *,
    where: Optional[jax.Array] = None,
    axis: int = -1,
) -> jax.Array:
    if where is not None:
        raise CapabilityError(
            "softmax backend 'pallas' does not take a `where` mask (the "
            "kernel streams dense row tiles); mask upstream or use "
            "impl='reference'"
        )
    moved = axis % x.ndim != x.ndim - 1
    if moved:
        x = jnp.moveaxis(x, axis, -1)
    out = star_softmax_pallas(
        x,
        fmt=spec.fmt,
        block_rows=spec.block_rows,
        use_histogram=spec.mode == "histogram",
        use_mxu_lut=spec.mode == "onehot",
        interpret=spec.interpret,
        fault=spec.fault,
    )
    if moved:
        out = jnp.moveaxis(out, -1, axis)
    return out


register(
    "softmax",
    "reference",
    _softmax_reference,
    description="pure-jnp STAR engine / FP oracle (core.star_softmax)",
)
register(
    "softmax",
    "xla",
    _softmax_xla,
    capabilities={"kind": ("exact",), "fault": (None,)},
    description="jax.nn.softmax — the exact FP path, no quantization",
)
register(
    "softmax",
    "pallas",
    _softmax_pallas,
    capabilities={"kind": ("star",)},
    description="fused row-tile TPU kernel (kernels.star_softmax)",
)


# ---------------------------------------------------------------------------
# attention


def _attention_reference(
    spec: AttentionSpec,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_offset=0,
    kv_valid_len: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    return full_attention(
        q,
        k,
        v,
        softmax=SoftmaxConfig.from_spec(spec.softmax),
        causal=spec.causal,
        sliding_window=spec.sliding_window,
        q_offset=q_offset,
        kv_valid_len=kv_valid_len,
        scale=scale,
    )


def _attention_xla(
    spec: AttentionSpec,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_offset=0,
    kv_valid_len: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    # KV-block scanning is for long score rows.  For decode (tq == 1) it is
    # pure overhead — and with an SP-sharded cache the per-block re-slicing
    # forces XLA into involuntary resharding of the whole cache every layer
    # (the §Perf decode finding); the materialized einsum keeps the cache
    # sharding intact and lets the partial softmax reduce with one psum.
    # Under faults the online-rescale identity lut[a]*lut[b] == lut[a+b]
    # does not hold, so faulty calls always take the materialized path —
    # which also makes xla bit-identical to reference under any FaultModel.
    if (
        q.shape[1] == 1
        or k.shape[1] <= spec.block_kv
        or spec.softmax.fault is not None
    ):
        return _attention_reference(
            spec, q, k, v, q_offset=q_offset, kv_valid_len=kv_valid_len, scale=scale
        )
    return blocked_attention(
        q,
        k,
        v,
        softmax=SoftmaxConfig.from_spec(spec.softmax),
        causal=spec.causal,
        sliding_window=spec.sliding_window,
        q_offset=q_offset,
        kv_valid_len=kv_valid_len,
        scale=scale,
        block_size=spec.block_kv,
    )


def _attention_pallas(
    spec: AttentionSpec,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_offset=0,
    kv_valid_len: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    # Layout adapter: framework-native [B, T, H, D] -> the kernel's
    # [B, H, T, D], with (q_offset, per-batch valid lengths) packed into the
    # kernel's info vector.  The fused kernel always uses the arithmetic-LUT
    # dataflow; ``spec.softmax.mode`` is a dataflow hint for the unfused
    # engines and is ignored here.
    b, _, _, _ = q.shape
    tk = k.shape[1]
    if kv_valid_len is None:
        kv_valid_len = jnp.full((b,), tk, dtype=jnp.int32)
    info = jnp.concatenate(
        [jnp.asarray(q_offset, jnp.int32).reshape(1), kv_valid_len.astype(jnp.int32)]
    )
    qh = jnp.transpose(q, (0, 2, 1, 3))
    kh = jnp.transpose(k, (0, 2, 1, 3))
    vh = jnp.transpose(v, (0, 2, 1, 3))
    out = flash_star_attention(
        qh,
        kh,
        vh,
        info,
        fmt=spec.softmax.fmt,  # None for the exact kind
        causal=spec.causal,
        sliding_window=spec.sliding_window,
        sm_scale=scale,
        block_q=spec.block_q,
        block_k=spec.block_k,
        pv_int8=spec.pv_int8,
        interpret=spec.interpret,
    )
    return jnp.transpose(out, (0, 2, 1, 3))


register(
    "attention",
    "reference",
    _attention_reference,
    capabilities={"pv_int8": (False,)},
    description="whole-operand attention, scores materialized (core.attention)",
)
register(
    "attention",
    "xla",
    _attention_xla,
    capabilities={"pv_int8": (False,)},
    description="online-blocked lax.scan pipeline (falls back to the "
    "materialized path for short rows / single-token decode)",
)
register(
    "attention",
    "pallas",
    _attention_pallas,
    # online-rescale kernel: no per-cell fault path (see DESIGN.md §9)
    capabilities={"softmax.kind": ("star", "exact"), "softmax.fault": (None,)},
    description="fused flash_star TPU kernel (kernels.flash_star)",
)
register(
    "attention",
    "paged",
    _attention_xla,
    capabilities={"pv_int8": (False,)},
    description="paged KV-cache marker impl: dense invocations (prefill, "
    "lockstep) run the xla pipeline; the serve stack reads this impl as "
    "'use the block-pool cache' and routes decode through the "
    "paged_attention op (ops.use(attention='paged') flips both at once)",
)


# ---------------------------------------------------------------------------
# paged attention (block-pool KV cache decode — DESIGN.md §8)


def _gather_pages(
    k_pages: jax.Array,  # [N, bs, Hkv, D]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [S, W] int32
    kv_len: Optional[int],
    kv_scales: Optional[tuple] = None,  # (k_scale, v_scale), each [N, Hkv]
) -> tuple:
    """Concatenate each sequence's blocks: -> dense [S, kv_len, Hkv, D].

    Logical row ``i`` lives at ``(table[i // bs], i % bs)`` (the
    serve.paged layout invariant), so reshaping the gathered blocks
    reproduces the dense per-slot cache row exactly; rows past ``kv_len``
    (block-grid overshoot) are dropped, rows past the caller's
    ``kv_valid_len`` are masked downstream.

    With ``kv_scales`` the pages hold quantized codes: each gathered block
    is dequantized through its own (block, head) scale — the same
    ``codes.astype(f32) * scale`` expression the paged kernel evaluates in
    place, so this gathered view is the kernel's dequant *oracle*
    (DESIGN.md §13).
    """
    s, w = block_tables.shape
    n, bs, hkv, d = k_pages.shape
    flat = block_tables.reshape(-1)
    kd = jnp.take(k_pages, flat, axis=0)
    vd = jnp.take(v_pages, flat, axis=0)
    if kv_scales is not None:
        k_scale, v_scale = kv_scales
        ks = jnp.take(k_scale, flat, axis=0)[:, None, :, None]  # [S*W,1,Hkv,1]
        vs = jnp.take(v_scale, flat, axis=0)[:, None, :, None]
        kd = kvquant.decode(kd, ks)
        vd = kvquant.decode(vd, vs)
    kd = kd.reshape(s, w * bs, hkv, d)
    vd = vd.reshape(s, w * bs, hkv, d)
    if kv_len is not None and kv_len < w * bs:
        kd = kd[:, :kv_len]
        vd = vd[:, :kv_len]
    return kd, vd


def _paged_dense_spec(spec: PagedAttentionSpec, impl: str) -> AttentionSpec:
    # Ragged valid lengths subsume causality for decode (DESIGN.md §6):
    # the gathered call is causal=False + kv_valid_len, like the dense
    # per-slot path.
    return AttentionSpec(
        impl=impl,
        softmax=spec.softmax,
        causal=False,
        ragged=True,
        block_q=spec.block_q,
        block_k=spec.block_k,
        interpret=spec.interpret,
    )


def _make_paged_backend(impl: str, dense_fn):
    """Adapter shared by every paged backend: gather the page pool through
    the block tables (in XLA — scatter/gather is not MXU work), then hand
    the dense view plus the ragged valid lengths to the matching dense
    attention backend (the pallas one packs them into the fused kernel's
    info vector)."""

    def fn(
        spec: PagedAttentionSpec,
        q: jax.Array,
        k_pages: jax.Array,
        v_pages: jax.Array,
        block_tables: jax.Array,
        *,
        kv_valid_len: jax.Array,
        kv_len: Optional[int] = None,
        scale: Optional[float] = None,
        kv_scales: Optional[tuple] = None,
    ) -> jax.Array:
        kd, vd = _gather_pages(k_pages, v_pages, block_tables, kv_len, kv_scales)
        return dense_fn(
            _paged_dense_spec(spec, impl),
            q,
            kd,
            vd,
            kv_valid_len=kv_valid_len,
            scale=scale,
        )

    return fn


register(
    "paged_attention",
    "reference",
    _make_paged_backend("reference", _attention_reference),
    capabilities={"kv_dtype": kvquant.KV_DTYPES},
    description="block-table gather + whole-operand ragged decode "
    "(core.attention); quantized pools dequantize at gather time — the "
    "paged kernel's dequant oracle",
)
register(
    "paged_attention",
    "xla",
    _make_paged_backend("xla", _attention_xla),
    capabilities={"kv_dtype": kvquant.KV_DTYPES},
    description="block-table gather via jnp.take + the online-blocked "
    "dense pipeline over ragged valid lengths (dequant oracle for "
    "quantized pools)",
)
register(
    "paged_attention",
    "pallas",
    _make_paged_backend("pallas", _attention_pallas),
    # online-rescale kernel: no per-cell fault path (see DESIGN.md §9)
    capabilities={
        "softmax.kind": ("star", "exact"),
        "softmax.fault": (None,),
        "kv_dtype": kvquant.KV_DTYPES,
    },
    description="block-table gather + fused flash_star kernel with the "
    "ragged-length info vector (kernels.flash_star)",
)


def _paged_pallas_paged(
    spec: PagedAttentionSpec,
    q: jax.Array,  # [S, Tq(=1), Hq, D]
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    *,
    kv_valid_len: jax.Array,
    kv_len: Optional[int] = None,
    scale: Optional[float] = None,
    kv_scales: Optional[tuple] = None,
) -> jax.Array:
    """Gather-free decode: the kernel walks the block table in place."""
    if q.shape[1] != 1:
        raise CapabilityError(
            "paged_attention backend 'pallas_paged' is a decode kernel "
            f"(one query token per slot); got Tq={q.shape[1]}. Use a "
            "gather backend for multi-token paged queries."
        )
    valid = kv_valid_len.astype(jnp.int32)
    if kv_len is not None:
        # ring caches: the live window is the valid prefix of the buffer
        valid = jnp.minimum(valid, jnp.int32(kv_len))
    k_scale, v_scale = kv_scales if kv_scales is not None else (None, None)
    out = paged_flash_attention(
        q[:, 0],
        k_pages,
        v_pages,
        block_tables,
        valid,
        fmt=spec.softmax.fmt,  # None for the exact kind
        sm_scale=scale,
        interpret=spec.interpret,
        k_scale=k_scale,
        v_scale=v_scale,
    )
    return out[:, None]


register(
    "paged_attention",
    "pallas_paged",
    _paged_pallas_paged,
    # same fused-kernel envelope as flash_star: no per-cell fault path
    capabilities={
        "softmax.kind": ("star", "exact"),
        "softmax.fault": (None,),
        "kv_dtype": kvquant.KV_DTYPES,
    },
    description="gather-free scalar-prefetch decode kernel: the grid "
    "walks (slot, kv_head, kv_block) and DMA-fetches only table-named "
    "pages; quantized pools dequantize in-kernel with the scale pages "
    "riding scalar prefetch (kernels.paged_attention)",
)


def paged_gather_bytes(
    impl: str,
    *,
    table_width: int,
    block_size: int,
    live_lens,
    num_kv_heads: int,
    head_dim: int,
    dtype_bytes: int = 4,
    scale_bytes_per_block: int = 0,
) -> int:
    """Counted K+V bytes one paged decode step reads from the page pool.

    The gather adapters (``reference``/``xla``/``pallas``) materialize
    every slot's whole table window — ``S * W * bs`` rows — before the
    dense kernel runs.  ``pallas_paged`` DMA-fetches only each slot's live
    pages: ``sum(ceil(live / bs)) * bs`` rows (free slots still touch the
    one clamped page, matching the kernel's DMA-elision behaviour).  This
    is the interpret-normalized traffic model behind
    ``gather_bytes_per_token`` in ``kv_stats``/benchmarks — a counted
    quantity, not a measurement.

    ``dtype_bytes`` is the page-pool leaf itemsize (1 for int8/fp8 codes);
    ``scale_bytes_per_block`` adds the K+V scale-page bytes a quantized
    layout reads per touched block (0 for fp32 — DESIGN.md §13).
    """
    row_bytes = 2 * num_kv_heads * head_dim * dtype_bytes  # K and V
    lens = [int(x) for x in live_lens]
    if impl == "pallas_paged":
        blocks = sum(max(-(-live // block_size), 1) for live in lens)
    else:
        blocks = len(lens) * table_width
    return blocks * (block_size * row_bytes + scale_bytes_per_block)


# ---------------------------------------------------------------------------
# matmul


def _matmul_xla(spec: MatmulSpec, x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w)


def _matmul_hwmodel(spec: MatmulSpec, x: jax.Array, w: jax.Array) -> jax.Array:
    """x [M, K] @ w [K, N] through the RRAM crossbar behavioural model.

    With a ``spec.fault``, the stored weights pick up seeded cell faults
    (float32 — off the int grid by construction) and each tile's ADC an
    input-referred offset; calibration (``adc_step``) observes the faulty
    array, as a deployed design would.
    """
    xbar = spec.crossbar
    n = w.shape[1]
    (xq, sx), (wq, sw) = quantize_operands(x, w, xbar)
    xq = _pad_to(xq, 1, xbar.tile_rows)
    wq = _pad_to(_pad_to(wq, 0, xbar.tile_rows), 1, xbar.tile_cols)
    wq = apply_weight_faults(wq, xbar, spec.fault)
    step = adc_step(xq, wq, xbar, spec.ranging)
    offsets = None
    if spec.fault is not None:
        kt = xq.shape[1] // xbar.tile_rows
        nt = wq.shape[1] // xbar.tile_cols
        offsets = faults_lib.adc_tile_offsets(spec.fault, (kt, nt))
    out = crossbar_matmul_pallas(
        xq.astype(jnp.int8) if xbar.weight_bits <= 8 else xq,
        wq if spec.fault is not None
        else (wq.astype(jnp.int8) if xbar.weight_bits <= 8 else wq),
        step,
        offsets,
        spec=xbar,
        block_m=spec.block_m,
        interpret=spec.interpret,
    )
    return out[:, :n] * (sx * sw)


register(
    "matmul",
    "xla",
    _matmul_xla,
    capabilities={"fault": (None,)},
    description="native MXU matmul — the performance path",
)
register(
    "matmul",
    "hwmodel",
    _matmul_hwmodel,
    description="RRAM crossbar behavioural model: 8-bit operands on "
    "tile_rows x tile_cols crossbars through a 5-bit ADC "
    "(kernels.crossbar_matmul)",
)


# ---------------------------------------------------------------------------
# ssd_scan (mamba2 fused mixer — no softmax, same dispatch machinery)


def _ssd_scan_pallas(spec: ScanSpec, xdt, a, bmat, cmat):
    return ssd_scan_pallas(
        xdt, a, bmat, cmat, chunk=spec.chunk, interpret=spec.interpret
    )


def _ssd_scan_reference(spec: ScanSpec, xdt, a, bmat, cmat):
    # Lazy import: the reference delegates to the model's own chunked SSD
    # (repro.models imports repro.ops at module load — importing it here
    # at call time keeps the layering acyclic).
    from repro.kernels.ssd_scan.ref import ssd_scan_ref

    return ssd_scan_ref(xdt, a, bmat, cmat, chunk=spec.chunk)


register(
    "ssd_scan",
    "pallas",
    _ssd_scan_pallas,
    description="fused SSD chunk-scan TPU kernel (kernels.ssd_scan)",
)
register(
    "ssd_scan",
    "reference",
    _ssd_scan_reference,
    description="pure-jnp chunked SSD oracle (models.ssm via kernels.ssd_scan.ref)",
)
