"""Capability-checked backend registry for the ``repro.ops`` dispatch layer.

Backends register under ``(op, impl)`` keys with a declarative capability
table: a mapping from spec field path (dotted paths reach nested specs,
e.g. ``"softmax.kind"``) to the tuple of values the backend supports.
Dispatch validates the spec against the table before calling the backend,
so a mismatch fails with an actionable error — which field, what the
backend supports, and which registered impls *do* support the request —
instead of a shape error three layers down.

``use(...)`` pushes a context-local override frame: tests and benchmarks
can retarget every dispatch (``use(softmax="reference")``, or
``use(interpret=True)``) without threading kwargs through call sites.
"""

from __future__ import annotations

import contextlib
import dataclasses
from contextvars import ContextVar
from typing import Any, Callable, Dict, Iterator, Mapping, Optional, Tuple


class OpDispatchError(ValueError):
    """Base class for dispatch-layer errors."""


class UnknownBackendError(OpDispatchError):
    """No backend registered under the requested (op, impl)."""


class CapabilityError(OpDispatchError):
    """The selected backend cannot execute the requested spec."""


@dataclasses.dataclass(frozen=True)
class Backend:
    """One registered implementation of an op.

    ``fn(spec, *args, **kwargs)`` receives the fully-resolved spec (impl
    overrides applied, ``interpret`` concrete) plus the runtime arrays.
    ``capabilities`` maps spec field paths to allowed value tuples; fields
    not listed are unconstrained.
    """

    op: str
    impl: str
    fn: Callable[..., Any]
    capabilities: Mapping[str, Tuple[Any, ...]] = dataclasses.field(
        default_factory=dict
    )
    description: str = ""


_REGISTRY: Dict[Tuple[str, str], Backend] = {}


def register(
    op: str,
    impl: str,
    fn: Callable[..., Any],
    *,
    capabilities: Optional[Mapping[str, Tuple[Any, ...]]] = None,
    description: str = "",
    overwrite: bool = False,
) -> Backend:
    """Register (or with ``overwrite=True`` replace) a backend."""
    key = (op, impl)
    if key in _REGISTRY and not overwrite:
        raise OpDispatchError(
            f"backend {impl!r} already registered for op {op!r}; "
            f"pass overwrite=True to replace it"
        )
    backend = Backend(op, impl, fn, dict(capabilities or {}), description)
    _REGISTRY[key] = backend
    return backend


def unregister(op: str, impl: str) -> None:
    _REGISTRY.pop((op, impl), None)


def get(op: str, impl: str) -> Backend:
    backend = _REGISTRY.get((op, impl))
    if backend is None:
        known = sorted(b.impl for b in backends(op))
        if not known:
            raise UnknownBackendError(
                f"no backends registered for op {op!r} "
                f"(is repro.ops.impls imported?)"
            )
        raise UnknownBackendError(
            f"no {op!r} backend named {impl!r}; registered impls: {known}"
        )
    return backend


def backends(op: str) -> Tuple[Backend, ...]:
    """All registered backends for an op, sorted by impl name."""
    found = [b for (o, _), b in _REGISTRY.items() if o == op]
    return tuple(sorted(found, key=lambda b: b.impl))


def registered_ops() -> Tuple[str, ...]:
    """All op names with at least one registered backend."""
    return tuple(sorted({o for (o, _) in _REGISTRY}))


def _field_value(spec: Any, path: str) -> Any:
    value = spec
    for part in path.split("."):
        value = getattr(value, part)
    return value


def validate(backend: Backend, spec: Any) -> None:
    """Raise :class:`CapabilityError` unless ``backend`` can execute ``spec``."""
    for path, allowed in backend.capabilities.items():
        value = _field_value(spec, path)
        if value not in allowed:
            others = [
                b.impl
                for b in backends(backend.op)
                if b.impl != backend.impl
                and _field_value(spec, path) in b.capabilities.get(path, (value,))
            ]
            hint = (
                f"; impls supporting {path}={value!r}: {sorted(others)}"
                if others
                else ""
            )
            raise CapabilityError(
                f"{backend.op} backend {backend.impl!r} does not support "
                f"{path}={value!r} (supported: {list(allowed)}){hint}"
            )


# --- context-local overrides (ops.use) -------------------------------------

_OVERRIDE_FRAMES: ContextVar[Tuple[Mapping[str, Any], ...]] = ContextVar(
    "repro_ops_overrides", default=()
)

_OVERRIDE_KEYS = (
    "softmax",
    "attention",
    "paged_attention",
    "matmul",
    "ssd_scan",
    "interpret",
)


@contextlib.contextmanager
def use(**overrides: Any) -> Iterator[None]:
    """Context manager retargeting dispatch inside the ``with`` block.

    Keys are op names (value: impl name to force) or ``interpret`` (value:
    bool forced onto every spec).  Inner frames win over outer frames; both
    win over the spec's own ``impl``/``interpret`` — that is the point:
    tests and benchmarks can re-route code that pinned a backend.

        with ops.use(softmax="reference", interpret=True):
            ...  # every softmax dispatch runs the pure-jnp engine

    Overrides resolve at *trace* time: enter the context before jitting
    (or tracing) the function you want retargeted — a function traced
    outside the block keeps the backend it was traced with.
    """
    bad = sorted(set(overrides) - set(_OVERRIDE_KEYS))
    if bad:
        raise OpDispatchError(
            f"unknown ops.use() keys {bad}; valid keys: {list(_OVERRIDE_KEYS)}"
        )
    token = _OVERRIDE_FRAMES.set(_OVERRIDE_FRAMES.get() + (dict(overrides),))
    try:
        yield
    finally:
        _OVERRIDE_FRAMES.reset(token)


def active_overrides(op: str) -> Dict[str, Any]:
    """Collapse the override stack for one op: {'impl': ..., 'interpret': ...}."""
    out: Dict[str, Any] = {}
    for frame in _OVERRIDE_FRAMES.get():
        if op in frame:
            out["impl"] = frame[op]
        if "interpret" in frame:
            out["interpret"] = frame["interpret"]
    return out
