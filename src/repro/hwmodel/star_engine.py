"""System-level model: STAR softmax engine + MatMul engine + pipeline.

Reproduces Table I (softmax engine area/power vs CMOS baseline and
Softermax) and Fig 3 (computing efficiency vs GPU / PipeLayer /
ReTransformer) from component constants.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.hwmodel import constants as C
from repro.hwmodel.crossbar import cam_crossbar, lut_crossbar, vmm_crossbar, XbarCost


# ---------------------------------------------------------------------------
# Table I: the softmax engine alone


def star_softmax_engine_cost() -> XbarCost:
    """CAM/SUB + CAM + LUT + VMM crossbars + counter + divider (paper §III)."""
    camsub = cam_crossbar(C.CAMSUB_ROWS, C.CAMSUB_COLS)
    cam = cam_crossbar(C.CAM_ROWS, C.CAM_COLS)
    lut = lut_crossbar(C.CAM_ROWS, C.CAM_COLS)
    vmm = vmm_crossbar(C.CAM_ROWS, C.CAM_COLS, n_adc=C.N_ADC_SOFTMAX)
    area = (
        camsub.area_mm2 + cam.area_mm2 + lut.area_mm2 + vmm.area_mm2
        + C.DIVIDER_AREA + C.COUNTER_AREA
    )
    power = (
        camsub.power_w + cam.power_w + lut.power_w + vmm.power_w
        + C.DIVIDER_POWER + C.COUNTER_POWER
    )
    # one softmax vector (length d): d CAM searches pipelined with LUT reads,
    # one VMM read for the sum, one divide pass
    return XbarCost(area, power, C.CAM_SEARCH_TIME)


def table1() -> Dict[str, Dict[str, float]]:
    ours = star_softmax_engine_cost()
    rel_area = ours.area_mm2 / C.CMOS_SOFTMAX_AREA
    rel_power = ours.power_w / C.CMOS_SOFTMAX_POWER
    return {
        "baseline_cmos": {"area": 1.0, "power": 1.0},
        "softermax": {"area": C.SOFTERMAX_REL_AREA, "power": C.SOFTERMAX_REL_POWER},
        "ours_model": {"area": rel_area, "power": rel_power},
        "ours_paper": {"area": 0.06, "power": 0.05},
        "ours_abs": {"area_mm2": ours.area_mm2, "power_w": ours.power_w},
        "vs_softermax_model": {
            "area": rel_area / C.SOFTERMAX_REL_AREA,
            "power": rel_power / C.SOFTERMAX_REL_POWER,
        },
        "vs_softermax_paper": {"area": 0.20, "power": 0.44},
    }


# ---------------------------------------------------------------------------
# Fig 3: system computing efficiency (GOPS/s/W) on BERT-base attention


def _attention_workload(seq: int) -> Dict[str, float]:
    d, h = C.BERT_D_MODEL, C.BERT_HEADS
    mm_ops = 2 * seq * d * d * 4 + 2 * 2 * seq * seq * d  # QKVO + QK^T + PV
    mm_ops += 2 * 2 * seq * d * C.BERT_FF  # FFN
    softmax_elems = h * seq * seq
    softmax_ops = 5 * softmax_elems  # exp + max + sub + sum + div per element
    return {"mm_ops": mm_ops, "softmax_ops": softmax_ops, "softmax_elems": softmax_elems}


def matmul_engine_cost() -> XbarCost:
    x = vmm_crossbar(C.MM_XBAR_ROWS, C.MM_XBAR_COLS, n_adc=C.MM_ADCS_PER_XBAR)
    return XbarCost(
        x.area_mm2 * C.MM_N_XBARS, x.power_w * C.MM_N_XBARS, x.op_time_s
    )


def system_efficiency(seq: int = 128, softmax_on_rram: bool = True,
                      vector_pipeline: bool = True) -> Dict[str, float]:
    """GOPS/s/W for the RRAM attention accelerator.

    softmax_on_rram=False, vector_pipeline=False  -> ReTransformer-like
    softmax_on_rram=True,  vector_pipeline=True   -> STAR
    """
    w = _attention_workload(seq)
    mm = matmul_engine_cost()
    sm = star_softmax_engine_cost()

    # MatMul engine throughput: ops per crossbar read x crossbars
    mm_ops_per_read = 2 * C.MM_XBAR_ROWS * C.MM_XBAR_COLS
    mm_time = (w["mm_ops"] / (mm_ops_per_read * C.MM_N_XBARS)
               * C.XBAR_READ_TIME * C.MM_SERIALIZATION)

    if softmax_on_rram:
        # one CAM search + LUT read per element, fully pipelined
        sm_time = w["softmax_elems"] * C.CAM_SEARCH_TIME
        sm_power = sm.power_w
    else:
        # digital softmax on the thin shared vector unit (the paper's
        # premise: softmax runs at operand granularity on general circuits)
        sm_time = w["softmax_ops"] / C.CMOS_SOFTMAX_OPS_PER_S
        sm_power = C.CMOS_SOFTMAX_POWER

    if vector_pipeline:
        # vector-grained pipeline: softmax overlaps matmul; the engine-level
        # critical path is max(mm, softmax) plus a fill bubble
        total_time = max(mm_time, sm_time) * 1.08
    else:
        # operand-grained: stages serialize
        total_time = mm_time + sm_time

    total_ops = w["mm_ops"] + w["softmax_ops"]
    total_power = mm.power_w + sm_power
    gops_per_w = total_ops / total_time / total_power / 1e9
    return {
        "gops_per_w": gops_per_w,
        "mm_time": mm_time,
        "softmax_time": sm_time,
        "softmax_share": sm_time / (mm_time + sm_time),
        "power_w": total_power,
    }


def fig3(seq: int = 128) -> Dict[str, float]:
    star = system_efficiency(seq, softmax_on_rram=True, vector_pipeline=True)
    retr = system_efficiency(seq, softmax_on_rram=False, vector_pipeline=False)
    return {
        "star_model": star["gops_per_w"],
        "retransformer_model": retr["gops_per_w"],
        "star_paper": C.STAR_EFFICIENCY_PAPER,
        "retransformer_paper": C.RETRANSFORMER_EFFICIENCY,
        "pipelayer_paper": C.PIPELAYER_EFFICIENCY,
        "gpu_paper": C.GPU_EFFICIENCY,
        "star_vs_gpu_model": star["gops_per_w"] / C.GPU_EFFICIENCY,
        "star_vs_retransformer_model": star["gops_per_w"] / retr["gops_per_w"],
        "star_vs_gpu_paper": 30.63,
        "star_vs_retransformer_paper": 1.31,
    }
