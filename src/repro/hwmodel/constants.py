"""Hardware constants for the analytical RRAM/CMOS cost model.

Provenance tags:
  [paper]   — value stated in the STAR paper itself
  [lit]     — published literature value (ISAAC/PipeLayer/NeuroSim/Softermax)
  [derived] — computed from the above
  [calib]   — calibrated so the model lands inside the published envelope
              (the paper reports only *ratios*; absolute scale needs one
              anchor per table, which is standard for no-RTL reproduction)

All areas mm^2, powers W, times s, energies J.  Node: 32 nm.
"""

# ---- RRAM crossbar primitives (NeuroSim-era, 32nm) --------------------------
RRAM_CELL_AREA = 0.04e-6  # mm^2 per 1T1R cell (~40F^2 incl. wiring) [lit]
XBAR_READ_TIME = 100e-9  # one VMM read incl. ADC [lit: ISAAC/PipeLayer]
XBAR_READ_ENERGY_PER_CELL = 0.08e-12  # J per active cell per read [lit]
CAM_SEARCH_TIME = 2e-9  # parallel match-line search [lit: RRAM TCAM]
CAM_SEARCH_ENERGY_PER_ROW = 0.4e-15  # J per row per search [lit]

# peripheral overheads (per crossbar)
ADC5_AREA = 0.0012  # 5-bit SAR ADC [lit: ISAAC 8b=0.0096mm^2, scaled]
ADC5_POWER = 1.0e-3  # W at read rate [lit]
DRIVER_AREA_PER_ROW = 0.10e-6  # mm^2 (DAC/WL driver) [lit]
SA_AREA_PER_COL = 0.06e-6  # sense amp per column [lit]
PERIPH_POWER_PER_XBAR = 0.15e-3  # controllers, mux [calib]

# ---- STAR softmax engine geometry (paper Section III) -----------------------
CAMSUB_ROWS, CAMSUB_COLS = 512, 18  # [paper]
CAM_ROWS, CAM_COLS = 256, 18  # [paper] (also LUT, VMM crossbars)
N_ADC_SOFTMAX = 2  # shared ADCs across the small softmax crossbars [calib]
DIVIDER_AREA = 0.002  # digital divider, 32nm [lit]
DIVIDER_POWER = 0.8e-3  # [lit]
COUNTER_AREA = 0.0004  # 256-bin counter array [lit]
COUNTER_POWER = 0.2e-3  # [lit]

# ---- baseline digital softmax unit (seq 128, 8-bit) -------------------------
# A straightforward pipelined CMOS softmax (exp LUT per lane + adder tree +
# divider), 16 lanes; absolute scale anchored to Softermax's reported
# baseline envelope. [calib anchored on lit]
CMOS_SOFTMAX_AREA = 0.10  # mm^2 [calib anchor for Table I area scale]
CMOS_SOFTMAX_POWER = 0.165  # W [calib anchor for Table I power scale]
# Softermax relative numbers [paper Table I / Softermax paper]
SOFTERMAX_REL_AREA = 0.33
SOFTERMAX_REL_POWER = 0.12

# ---- MatMul engine (follows ReTransformer) ----------------------------------
MM_XBAR_ROWS = MM_XBAR_COLS = 128  # [paper]
MM_ADC_BITS = 5  # [paper]
MM_N_XBARS = 64  # engine tile count [calib to ReTransformer scale]
MM_ADCS_PER_XBAR = 4  # column-shared [lit: ISAAC-style sharing]
# effective serialization of one logical 128x128 VMM: 32:1 column mux with
# input-bit pipelining overlap ~0.9 -> 28.6 reads per VMM [calib]
MM_SERIALIZATION = 28.6
# thin digital vector unit on PipeLayer/ReTransformer-class designs that
# the softmax falls back to (the paper's premise) [calib]
CMOS_SOFTMAX_OPS_PER_S = 2.42e9

# ---- published baseline system efficiencies (GOPS/s/W) ----------------------
GPU_EFFICIENCY = 20.0  # Titan RTX on BERT attention [paper: 612.66/30.63]
PIPELAYER_EFFICIENCY = 141.8  # [paper: 612.66/4.32; PipeLayer-era]
RETRANSFORMER_EFFICIENCY = 467.7  # [paper: 612.66/1.31]
STAR_EFFICIENCY_PAPER = 612.66  # [paper]

# ---- BERT-base attention workload (paper's evaluation model) ----------------
BERT_D_MODEL = 768
BERT_HEADS = 12
BERT_FF = 3072
