"""Device non-ideality models for the RRAM crossbar engines.

STAR's efficiency argument rests on softmax being precision-insensitive —
but a real RRAM deployment adds error sources *beyond* quantization that
the fixed-point analysis cannot see:

* **conductance variation** — programmed conductances land lognormally
  around their target (cycle-to-cycle / device-to-device variation);
* **stuck-at faults** — forming failures and worn cells read as G_on
  (always max conductance) or G_off (always zero) regardless of what was
  programmed;
* **ADC offset drift** — the shared SAR ADCs carry a per-instance input
  offset (modeled in LSB units of the ADC step);
* **read disturb** — repeated reads drift conductances toward G_off; we
  model the *accumulated* drift as a multiplicative decay ``exp(-r)``.

:class:`FaultModel` is a frozen, hashable realization description: the
``seed`` plus per-site tags fully determine every mask and noise draw via
explicit ``jax.random`` keys (:func:`fault_key`) — no global RNG state, so
the same model produces bit-identical injections across calls, jit
boundaries, and processes.  Specs carry an optional ``fault`` field
(``repro.ops.specs``) so a fault realization rides the same dispatch
machinery as precision: it is part of *what* is computed.

Site tag convention (one realization per physical array):

=================  ==================================================
``softmax/lut``    the numerator LUT crossbar contents
``softmax/vmm``    the denominator VMM crossbar (independent copy)
``softmax/cam``    the CAM match array (broken rows remap — see
                   :func:`cam_remap`)
``softmax/adc``    the shared softmax-engine ADC (denominator gain)
``matmul/w``       MatMul engine weight crossbar cells
``matmul/adc``     per-tile ADC offsets of the MatMul engine
=================  ==================================================
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import TYPE_CHECKING, Optional, Tuple

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # core imports hwmodel.faults — keep the cycle lazy
    from repro.core.fixedpoint import FixedPointFormat


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """One seeded realization of device non-idealities.

    All rates/sigmas default to zero, so ``FaultModel()`` is the ideal
    device (:attr:`is_null`); specs treat ``fault=None`` and a null model
    identically.  Frozen + hashable: safe as a jit static arg and inside
    frozen specs.
    """

    g_sigma: float = 0.0  # lognormal conductance variation (sigma of ln G)
    stuck_on_rate: float = 0.0  # P(cell stuck at G_on): reads as the max value
    stuck_off_rate: float = 0.0  # P(cell stuck at G_off): reads as zero
    adc_offset_sigma: float = 0.0  # ADC input offset, in LSB of the ADC step
    read_disturb: float = 0.0  # accumulated drift: G *= exp(-read_disturb)
    seed: int = 0  # realization seed — explicit keys derive from it

    def __post_init__(self) -> None:
        for f in ("g_sigma", "adc_offset_sigma", "read_disturb"):
            if getattr(self, f) < 0.0:
                raise ValueError(f"{f} must be >= 0, got {getattr(self, f)}")
        for f in ("stuck_on_rate", "stuck_off_rate"):
            if not 0.0 <= getattr(self, f) <= 1.0:
                raise ValueError(
                    f"{f} must be in [0, 1], got {getattr(self, f)}"
                )
        if self.stuck_on_rate + self.stuck_off_rate > 1.0:
            raise ValueError(
                "stuck_on_rate + stuck_off_rate must be <= 1, got "
                f"{self.stuck_on_rate} + {self.stuck_off_rate}"
            )

    @property
    def is_null(self) -> bool:
        """True when every non-ideality is switched off (the ideal device)."""
        return (
            self.g_sigma == 0.0
            and self.stuck_on_rate == 0.0
            and self.stuck_off_rate == 0.0
            and self.adc_offset_sigma == 0.0
            and self.read_disturb == 0.0
        )

    @property
    def stuck_rate(self) -> float:
        return self.stuck_on_rate + self.stuck_off_rate

    @classmethod
    def after_reads(
        cls, reads: int, disturb_per_read: float, **kwargs
    ) -> "FaultModel":
        """Model ``reads`` accumulated read-disturb events at a per-read
        drift rate (first-order: drifts compose multiplicatively)."""
        return cls(read_disturb=disturb_per_read * reads, **kwargs)


def is_null(fault: Optional[FaultModel]) -> bool:
    """``None`` and the all-zero model both mean "ideal device"."""
    return fault is None or fault.is_null


def fault_key(fault: FaultModel, tag: str) -> jax.Array:
    """Derive the jax.random key for one fault site.

    ``tag`` names the physical array (see the module table); folding a
    crc32 of each path segment keeps derivation deterministic across
    processes (``hash()`` is salted per process — never use it here).
    """
    key = jax.random.PRNGKey(fault.seed)
    for part in tag.split("/"):
        key = jax.random.fold_in(key, zlib.crc32(part.encode()) & 0x7FFFFFFF)
    return key


# ---------------------------------------------------------------------------
# cell-level injection


def stuck_masks(
    key: jax.Array, shape: Tuple[int, ...], fault: FaultModel
) -> Tuple[jax.Array, jax.Array]:
    """(stuck_on, stuck_off) boolean masks — disjoint, drawn from one
    uniform field so the partition is exact at any rate combination."""
    u = jax.random.uniform(key, shape)
    on = u < fault.stuck_on_rate
    off = (~on) & (u < fault.stuck_on_rate + fault.stuck_off_rate)
    return on, off


def apply_cell_faults(
    values: jax.Array,
    fault: FaultModel,
    tag: str,
    *,
    g_on: float,
    g_off: float = 0.0,
) -> jax.Array:
    """Perturb stored conductances: variation + read disturb + stuck-at.

    ``values`` are the programmed array contents (LUT entries, quantized
    weights); ``g_on``/``g_off`` are what a stuck cell *reads as* in that
    array's value domain.  Stuck-at wins over analog noise (the cell no
    longer responds to programming).
    """
    if is_null(fault):
        return values
    key = fault_key(fault, tag)
    k_noise, k_stuck = jax.random.split(key)
    out = values.astype(jnp.float32)
    if fault.g_sigma > 0.0 or fault.read_disturb > 0.0:
        # variation and disturb fold into ONE exponent and ONE multiply:
        # G * exp(sigma*eps - disturb).  The short op chain keeps XLA's
        # fusion-time contraction drift (eager vs jit) at the 1-ulp level;
        # within one compilation regime realizations are bit-identical.
        exponent = -jnp.float32(fault.read_disturb)
        if fault.g_sigma > 0.0:
            exponent = (
                fault.g_sigma * jax.random.normal(k_noise, values.shape)
                + exponent
            )
        out = out * jnp.exp(exponent)
    if fault.stuck_rate > 0.0:
        on, off = stuck_masks(k_stuck, values.shape, fault)
        out = jnp.where(on, jnp.float32(g_on), out)
        out = jnp.where(off, jnp.float32(g_off), out)
    return out


# ---------------------------------------------------------------------------
# softmax-engine sites (CAM / LUT / VMM / ADC)


def faulty_exp_lut(
    fmt: "FixedPointFormat", fault: FaultModel, tag: str = "softmax/lut"
) -> jax.Array:
    """The exp LUT crossbar under faults.  G_on reads as the top entry
    ``exp(0) = 1``; G_off as zero (the deepest row's ~0 probability)."""
    from repro.core import lut as lut_lib  # lazy: core imports this module

    return apply_cell_faults(
        lut_lib.exp_lut(fmt, dtype=jnp.float32), fault, tag, g_on=1.0, g_off=0.0
    )


def cam_remap(
    fmt: "FixedPointFormat", fault: FaultModel, tag: str = "softmax/cam"
) -> Optional[jax.Array]:
    """Match-index remap table ``[num_levels] int32`` for CAM stuck faults.

    A stuck CAM row cannot store its codebook pattern, so inputs that
    should match it match the nearest *working* row instead — deeper first
    (CAM out-of-range behaviour), shallower when no deeper row works.
    Returns ``None`` when the CAM is fault-free (identity remap elided).
    """
    if is_null(fault) or fault.stuck_rate == 0.0:
        return None
    levels = fmt.num_levels
    on, off = stuck_masks(fault_key(fault, tag), (levels,), fault)
    broken = on | off
    idx = jnp.arange(levels)
    # nearest working row at >= k: suffix-min over candidate indices
    cand = jnp.where(broken, levels, idx)
    deeper = jax.lax.associative_scan(jnp.minimum, cand, reverse=True)
    # rows with no working deeper row fall back to the nearest shallower one
    shallower = jax.lax.associative_scan(
        jnp.maximum, jnp.where(broken, -1, idx)
    )
    remap = jnp.where(deeper < levels, deeper, jnp.maximum(shallower, 0))
    return remap.astype(jnp.int32)


def adc_gain(fault: FaultModel, tag: str = "softmax/adc") -> Optional[float]:
    """Denominator gain of the softmax engine's shared ADC.

    The VMM sum passes one ADC whose input offset shows up (first order)
    as a multiplicative error on the denominator.  One scalar per
    realization — returns a concrete jnp scalar, ``None`` when ideal.
    """
    if is_null(fault) or fault.adc_offset_sigma == 0.0:
        return None
    eps = jax.random.normal(fault_key(fault, tag), ())
    return 1.0 + fault.adc_offset_sigma * eps


def adc_tile_offsets(
    fault: FaultModel, shape: Tuple[int, ...], tag: str = "matmul/adc"
) -> Optional[jax.Array]:
    """Per-crossbar-tile ADC input offsets in LSB units, shape ``[Kt, Nt]``.

    Added to ``partial / step`` before the ADC's round+clip — exactly an
    input-referred offset of a uniform quantizer.
    """
    if is_null(fault) or fault.adc_offset_sigma == 0.0:
        return None
    return fault.adc_offset_sigma * jax.random.normal(fault_key(fault, tag), shape)
