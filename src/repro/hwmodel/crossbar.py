"""Area / power / latency models for the RRAM crossbar primitives."""

from __future__ import annotations

import dataclasses

from repro.hwmodel import constants as C


@dataclasses.dataclass(frozen=True)
class XbarCost:
    area_mm2: float
    power_w: float  # at full duty
    op_time_s: float  # one operation (VMM read or CAM search)

    def scaled(self, duty: float) -> "XbarCost":
        return XbarCost(self.area_mm2, self.power_w * duty, self.op_time_s)


def vmm_crossbar(rows: int, cols: int, n_adc: int) -> XbarCost:
    """Analog VMM crossbar + shared ADCs + drivers."""
    area = (
        rows * cols * C.RRAM_CELL_AREA
        + rows * C.DRIVER_AREA_PER_ROW
        + cols * C.SA_AREA_PER_COL
        + n_adc * C.ADC5_AREA
    )
    # energy per read: active cells + ADC conversions
    e_read = rows * cols * C.XBAR_READ_ENERGY_PER_CELL
    power = e_read / C.XBAR_READ_TIME + n_adc * C.ADC5_POWER + C.PERIPH_POWER_PER_XBAR
    return XbarCost(area, power, C.XBAR_READ_TIME)


def cam_crossbar(rows: int, cols: int) -> XbarCost:
    """Content-addressable crossbar: parallel match-line search."""
    area = (
        rows * cols * C.RRAM_CELL_AREA
        + rows * C.DRIVER_AREA_PER_ROW
        + cols * C.SA_AREA_PER_COL
    )
    e_search = rows * C.CAM_SEARCH_ENERGY_PER_ROW
    power = e_search / C.CAM_SEARCH_TIME + C.PERIPH_POWER_PER_XBAR
    return XbarCost(area, power, C.CAM_SEARCH_TIME)


def lut_crossbar(rows: int, cols: int) -> XbarCost:
    """LUT read = one-hot driven row read (cheaper than full VMM: one row).

    Power audit (golden-locked in tests/test_hwmodel_golden.py): the LUT
    access is a row *read* — cell settle + SA sense, the same physics the
    per-cell read-energy constant was measured at — not a match-line
    search, so the read-power denominator is ``XBAR_READ_TIME``.  The
    engine still *issues* one LUT access per CAM search (banked rows keep
    the pipeline cadence), which is why ``op_time_s`` stays at the search
    cadence while full-duty power is per-read energy over the read time.
    """
    area = rows * cols * C.RRAM_CELL_AREA + rows * C.DRIVER_AREA_PER_ROW + cols * C.SA_AREA_PER_COL
    e_read = cols * C.XBAR_READ_ENERGY_PER_CELL  # single active row
    power = e_read / C.XBAR_READ_TIME + C.PERIPH_POWER_PER_XBAR
    return XbarCost(area, power, C.CAM_SEARCH_TIME)
