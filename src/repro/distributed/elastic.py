"""Elastic scaling: re-mesh planning + checkpoint-based resharding.

Checkpoints are mesh-agnostic (unsharded arrays), so elasticity reduces to:
  1. pick a new mesh for the surviving device count (``plan_mesh``),
  2. rebuild shardings from the same logical rules on the new mesh,
  3. ``checkpointer.restore(..., shardings=new)``.

``plan_mesh`` keeps the model axis as large as possible (TP degree is set
by model size, not fleet size) and gives the remainder to data.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import Rules, param_shardings


def plan_mesh(
    n_devices: int, *, model_parallel: int, devices=None
) -> Mesh:
    """Largest feasible (data, model) mesh for ``n_devices``."""
    mp = model_parallel
    while mp > 1 and n_devices % mp != 0:
        mp //= 2
    dp = n_devices // mp
    devs = devices if devices is not None else jax.devices()[:n_devices]
    import numpy as np

    return Mesh(np.asarray(devs).reshape(dp, mp), ("data", "model"))


def reshard_plan(specs_tree, rules: Rules, new_mesh: Mesh):
    """Shardings for restore() on the new mesh — same logical rules."""
    return param_shardings(specs_tree, rules, new_mesh)
