"""Explicit collective patterns: compressed gradient all-reduce (shard_map).

The pjit path lets XLA insert gradient all-reduces; this module is the
explicit alternative for bandwidth-constrained (cross-pod / DCN) axes:
int8 error-feedback compression cuts gradient all-reduce bytes 4x vs f32
(2x vs bf16) at negligible quality cost when the residual is fed back.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

PyTree = Any


def _ef_compress_allreduce(x: jax.Array, err: jax.Array, axis: str):
    """Error-feedback int8 all-reduce of a single tensor along ``axis``.

    Returns (mean, new_err).  Scale is the axis-max absmax so every shard
    quantizes on the same grid (required for int addition to be exact).
    """
    xf = x.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127)
    new_err = xf - q * scale
    total = jax.lax.psum(q, axis)  # int-valued f32; exact up to 2^24 shards
    n = jax.lax.psum(jnp.ones(()), axis)
    return (total * scale / n).astype(x.dtype), new_err


def compressed_grad_allreduce(
    grads: PyTree, err: PyTree, mesh: Mesh, axis: str = "data"
) -> Tuple[PyTree, PyTree]:
    """shard_map wrapper: per-shard grads -> error-feedback int8 mean.

    ``grads`` leaves must be replicated-per-shard values ALONG ``axis``
    (i.e. each data shard's local gradient).  Other mesh axes pass through.
    """

    def body(g_tree, e_tree):
        return jax.tree.map(
            lambda g, e: _ef_compress_allreduce(g, e, axis), g_tree, e_tree,
            is_leaf=lambda v: isinstance(v, jax.Array),
        )

    specs = jax.tree.map(lambda _: P(), grads)
    fn = _shard_map(
        lambda g, e: _split_pairs(body(g, e)),
        mesh=mesh,
        in_specs=(specs, specs),
        out_specs=(specs, specs),
    )
    return fn(grads, err)


def _split_pairs(tree_of_pairs: PyTree) -> Tuple[PyTree, PyTree]:
    is_pair = lambda v: isinstance(v, tuple) and len(v) == 2
    a = jax.tree.map(lambda p: p[0], tree_of_pairs, is_leaf=is_pair)
    b = jax.tree.map(lambda p: p[1], tree_of_pairs, is_leaf=is_pair)
    return a, b


def init_error_state(grads_like: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
