"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Models annotate parameters/activations with *logical* axes ("embed", "mlp",
"heads", "vocab", "expert", "batch", ...).  A rule table maps logical axes
to mesh axes; :func:`logical_to_pspec` resolves them with two safety rails:

  * **divisibility auto-drop** — a logical axis whose dim is not divisible
    by the mapped mesh axes is left unsharded (e.g. 8 KV heads on a
    16-way model axis degrade to replicated KV, exactly what you want);
  * **single-use** — a mesh axis may appear once per PartitionSpec; later
    dims drop it (e.g. EP expert dim + TP mlp dim both wanting "model").

``use_mesh_rules`` installs an ambient (mesh, rules) context so layer code
can call :func:`with_logical_constraint` without threading the mesh through
every function — outside the context it is an identity, which is what smoke
tests on one device want.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.param import ParamSpec, axes_tree

# Rule value: a mesh axis name, a tuple of mesh axis names, or None.
Rules = Dict[str, Any]

# Default rules for FSDP x TP on ("pod", "data", "model").  "pod" acts as an
# outer data axis; missing mesh axes are skipped so the same table serves
# single-pod and multi-pod meshes.
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "embed": ("data",),  # FSDP: weights sharded along embed over data
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "qkv": ("model",),
    "kv_seq": ("model",),  # decode-time KV cache sequence sharding (SP)
    "act_seq": ("model",),  # inter-block activation sequence parallelism
    "seq": (),
    "layers": (),
    "state": (),
    "conv": (),
}


def make_rules(**overrides: Any) -> Rules:
    rules = dict(DEFAULT_RULES)
    for k, v in overrides.items():
        if v is None:
            rules[k] = ()
        elif isinstance(v, str):
            rules[k] = (v,)
        else:
            rules[k] = tuple(v)
    return rules


def _normalize(rule: Any) -> Tuple[str, ...]:
    if rule is None:
        return ()
    if isinstance(rule, str):
        return (rule,)
    return tuple(rule)


def logical_to_pspec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    rules: Rules,
    mesh: Mesh,
) -> P:
    """Resolve logical axes to a PartitionSpec on ``mesh``."""
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        mesh_axes: Tuple[str, ...] = ()
        if name is not None:
            cand = [
                a
                for a in _normalize(rules.get(name, ()))
                if a in mesh.shape and a not in used
            ]
            # greedy prefix whose product divides the dim
            chosen = []
            prod = 1
            for a in cand:
                if dim % (prod * mesh.shape[a]) == 0:
                    chosen.append(a)
                    prod *= mesh.shape[a]
            mesh_axes = tuple(chosen)
            used.update(mesh_axes)
        if len(mesh_axes) == 0:
            entries.append(None)
        elif len(mesh_axes) == 1:
            entries.append(mesh_axes[0])
        else:
            entries.append(mesh_axes)
    return P(*entries)


def param_pspecs(specs_tree: Any, rules: Rules, mesh: Mesh) -> Any:
    """Tree of PartitionSpec matching a tree of ParamSpec."""
    return jax.tree.map(
        lambda s: logical_to_pspec(s.axes, s.shape, rules, mesh),
        specs_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_shardings(specs_tree: Any, rules: Rules, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, logical_to_pspec(s.axes, s.shape, rules, mesh)),
        specs_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Ambient mesh/rules context for activation constraints inside model code.

_ctx = threading.local()


@contextlib.contextmanager
def use_mesh_rules(mesh: Optional[Mesh], rules: Optional[Rules] = None):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules or DEFAULT_RULES) if mesh is not None else None
    try:
        yield
    finally:
        _ctx.state = prev


def current_mesh_rules():
    return getattr(_ctx, "state", None)


def with_logical_constraint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Apply a sharding constraint if a mesh context is active, else no-op."""
    state = current_mesh_rules()
    if state is None:
        return x
    mesh, rules = state
    spec = logical_to_pspec(axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def bytes_per_device(specs_tree: Any, rules: Rules, mesh: Mesh) -> int:
    """Parameter bytes resident per device under the rules (napkin math)."""
    total = 0
    leaves = jax.tree.leaves(specs_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    for s in leaves:
        pspec = logical_to_pspec(s.axes, s.shape, rules, mesh)
        shards = 1
        for entry in pspec:
            if entry is None:
                continue
            for a in _normalize(entry):
                shards *= mesh.shape[a]
        total += int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize // max(shards, 1)
    return total
