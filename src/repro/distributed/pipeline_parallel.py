"""GPipe-style pipeline parallelism via shard_map + collective_permute.

The production default for this framework is FSDP x TP (it dry-runs clean
at 512 chips), but >1T-param or cross-DCN deployments want PP on the slow
axis.  This module implements the schedule generically: stage-stacked
block params live on a ``stage`` mesh axis; microbatches stream through
with ppermute handoffs; the bubble is the standard (S-1)/(M+S-1).

The block function is user-supplied (h, block_params) -> h, so any of the
model families' scanned blocks can be pipelined without modification.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map


def _pvary(x: jax.Array, axis: str) -> jax.Array:
    """Mark ``x`` stage-varying for shard_map's vma typing (jax >= 0.6's
    ``lax.pcast``); older jax tracks replication itself — no-op there."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:  # pragma: no cover - version-dependent
        return x
    return pcast(x, (axis,), to="varying")

PyTree = Any


def pipeline_apply(
    block_fn: Callable[[jax.Array, PyTree], jax.Array],
    stage_params: PyTree,  # leaves [S, ...] (stage-major)
    x: jax.Array,  # [M, mb, ...] microbatched input
    mesh: Mesh,
    axis: str = "stage",
) -> jax.Array:
    """Run M microbatches through S pipeline stages.  Returns [M, mb, ...]."""
    s = mesh.shape[axis]
    m = x.shape[0]
    perm_fwd = [(i, (i + 1) % s) for i in range(s)]

    def stage_program(params, xs):
        # params arrive with a local size-1 stage dim — strip it
        params = jax.tree.map(lambda w: w[0], params)
        # xs: [M, mb, ...] — only stage 0 consumes real input
        idx = jax.lax.axis_index(axis)
        mb = xs.shape[1:]
        # mark carries stage-varying up front (shard_map vma typing)
        buf = _pvary(jnp.zeros(mb, xs.dtype), axis)
        outs = _pvary(jnp.zeros((m,) + mb, xs.dtype), axis)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any left)
            take = jnp.clip(t, 0, m - 1)
            buf = jnp.where(idx == 0, jnp.where(t < m, xs[take], buf), buf)
            # every stage computes its block
            buf = block_fn(buf, params)
            # last stage emits microbatch t - (s - 1)
            out_t = t - (s - 1)
            ot = jnp.clip(out_t, 0, m - 1)
            emit = (idx == s - 1) & (out_t >= 0) & (out_t < m)
            cur = jax.lax.dynamic_index_in_dim(outs, ot, 0, keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, buf, cur), ot, 0
            )
            # hand off to the next stage
            buf = jax.lax.ppermute(buf, axis, perm_fwd)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, m + s - 1, tick, (buf, outs))
        # deliver outputs from the last stage to everyone (results replicated)
        outs = jax.lax.psum(
            jnp.where(idx == s - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    pspecs = jax.tree.map(lambda _: P(axis), stage_params)
    fn = _shard_map(
        stage_program,
        mesh=mesh,
        in_specs=(pspecs, P()),
        out_specs=P(),
    )
    return fn(stage_params, x)
