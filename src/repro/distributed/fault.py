"""Fault-tolerance utilities: preemption handling + straggler watchdog.

On a 1000-node fleet the failure modes this module owns:
  * preemption (SIGTERM) -> flag the loop, checkpoint, clean exit;
  * stragglers -> per-step wall-time EMA; steps slower than
    ``threshold x EMA`` are logged and counted (hook point for
    backup-task dispatch at fleet scale);
  * crash recovery -> the loop auto-resumes from the newest intact
    checkpoint (atomic-rename saves make "intact" trivial).
"""

from __future__ import annotations

import signal
import time
from typing import Callable, List, Optional


class PreemptionGuard:
    """Installs a SIGTERM/SIGINT handler that sets a flag instead of dying."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False


class StragglerWatchdog:
    """EMA-based step-time monitor.

    ``observe(dt)`` returns True when the step is a straggler.  At fleet
    scale the hook would trigger backup execution / hot-spare swap; here it
    records the event for the training log and tests.
    """

    def __init__(self, threshold: float = 2.5, alpha: float = 0.1, warmup: int = 3):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.count = 0
        self.events: List[dict] = []

    def observe(self, dt: float, step: int = -1) -> bool:
        self.count += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = self.count > self.warmup and dt > self.threshold * self.ema
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        else:
            # stragglers do not poison the EMA
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


class FailureInjector:
    """Deterministic failure injection for restart tests."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step

    def maybe_fail(self, step: int) -> None:
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
