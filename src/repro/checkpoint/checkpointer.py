"""Atomic, mesh-agnostic checkpoints with rotation and auto-resume.

Layout: ``<dir>/step_<n>/`` containing one ``.npy`` per leaf (path-encoded
file names) + ``index.json``.  Writes go to ``step_<n>.tmp`` then rename —
a crashed writer never corrupts the latest checkpoint (fault tolerance
requirement).  Arrays are saved *unsharded* (device_get), so restore can
re-slice onto any mesh — this is what makes elastic rescaling work.  The
production-scale path (per-shard OCDBT writes) is a documented swap-in;
the semantics here are the contract.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "__"


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, state: PyTree) -> str:
    """Atomic save; returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(state)
    index = {"step": step, "leaves": []}
    for name, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        index["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "index.json"))
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    template: PyTree,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
) -> Tuple[PyTree, int]:
    """Restore into the structure of ``template``; optionally place with
    ``shardings`` (same structure) — re-slicing onto any mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    flat, treedef = _flatten(template)
    shard_flat = None
    if shardings is not None:
        shard_flat, _ = _flatten(shardings)
    leaves = []
    for i, (name, tmpl) in enumerate(flat):
        arr = np.load(os.path.join(final, name + ".npy"))
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i][1]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def rotate(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
