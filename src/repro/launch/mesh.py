"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2 pods x 256 = 512 chips (pod, data, model) — "pod" is the
slowest-varying axis (DCN-friendly outer data axis).

``jax.sharding.AxisType`` only exists from jax 0.5 (explicit-sharding
meshes); on older jax every mesh axis is Auto-typed anyway, so the
``axis_types`` kwarg is simply dropped there.
"""

from __future__ import annotations

import numpy as np

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _mesh_kwargs(num_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devs)} "
            "(dryrun.py must set XLA_FLAGS before any jax import)"
        )
    return jax.make_mesh(shape, axes, devices=devs[:n], **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes, devices=None):
    """Generic helper for tests/examples (Auto axis types)."""
    devs = devices if devices is not None else jax.devices()[: int(np.prod(shape))]
    return jax.make_mesh(
        tuple(shape), tuple(axes), devices=devs, **_mesh_kwargs(len(axes))
    )
