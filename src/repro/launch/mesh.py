"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2 pods x 256 = 512 chips (pod, data, model) — "pod" is the
slowest-varying axis (DCN-friendly outer data axis).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devs)} "
            "(dryrun.py must set XLA_FLAGS before any jax import)"
        )
    return jax.make_mesh(
        shape, axes, devices=devs[:n],
        axis_types=(AxisType.Auto,) * len(axes),
    )


def make_mesh(shape, axes, devices=None):
    """Generic helper for tests/examples (Auto axis types)."""
    devs = devices if devices is not None else jax.devices()[: int(np.prod(shape))]
    return jax.make_mesh(
        tuple(shape), tuple(axes), devices=devs,
        axis_types=(AxisType.Auto,) * len(axes),
    )
