"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

HBM_PER_CHIP = 16e9  # v5e


def load(dirpath: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def _fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def roofline_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = [r for r in recs if r["mesh"] == mesh and not r.get("tag")]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | step | t_compute | t_memory | t_collective | dominant | "
        "roofline frac | peak HBM/dev | fits 16GB | MODEL/HLO flops | coll breakdown |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        coll = r.get("collectives", {}).get("by_op", {})
        top = sorted(coll.items(), key=lambda kv: -kv[1])[:2]
        coll_s = ", ".join(f"{k.replace('collective-','c-')} {_fmt_b(v)}" for k, v in top) or "-"
        peak = r.get("peak_bytes_per_dev", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | "
            f"{_fmt_s(r['t_compute_s'])} | {_fmt_s(r['t_memory_s'])} | "
            f"{_fmt_s(r['t_collective_s'])} | **{r['dominant']}** | "
            f"{r['roofline_fraction']*100:.1f}% | {_fmt_b(peak)} | "
            f"{'yes' if peak <= HBM_PER_CHIP else 'NO'} | "
            f"{r['useful_flops_ratio']:.2f} | {coll_s} |"
        )
    return "\n".join(out)


def dryrun_table(recs: List[Dict], mesh: str) -> str:
    rows = [r for r in recs if r["mesh"] == mesh and not r.get("tag")]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | chips | compile | FLOPs/dev | bytes/dev | coll bytes/dev | peak HBM/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} | {r['compile_s']:.0f}s | "
            f"{r['flops_per_dev']:.3g} | {_fmt_b(r['bytes_per_dev'])} | "
            f"{_fmt_b(r['coll_bytes_per_dev'])} | {_fmt_b(r.get('peak_bytes_per_dev', 0))} |"
        )
    return "\n".join(out)


def summary(recs: List[Dict]) -> str:
    single = [r for r in recs if r["mesh"] == "single" and not r.get("tag")]
    multi = [r for r in recs if r["mesh"] == "multi" and not r.get("tag")]
    lines = [
        f"single-pod cells compiled: {len(single)} / 33",
        f"multi-pod cells compiled:  {len(multi)} / 33",
    ]
    by_dom: Dict[str, int] = {}
    for r in single:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    lines.append(f"dominant terms (single-pod): {by_dom}")
    worst = sorted(single, key=lambda r: r["roofline_fraction"])[:3]
    lines.append(
        "worst roofline fractions: "
        + ", ".join(f"{r['arch']}/{r['shape']} {r['roofline_fraction']*100:.1f}%" for r in worst)
    )
    most_coll = sorted(single, key=lambda r: -r["t_collective_s"])[:3]
    lines.append(
        "most collective-bound: "
        + ", ".join(f"{r['arch']}/{r['shape']} {_fmt_s(r['t_collective_s'])}" for r in most_coll)
    )
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    print("## Summary\n")
    print(summary(recs))
    print("\n## Roofline (single-pod, 256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n## Dry-run (multi-pod, 512 chips)\n")
    print(dryrun_table(recs, "multi"))


if __name__ == "__main__":
    main()
