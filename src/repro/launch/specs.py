"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

``input_specs(cfg, shape)`` returns (step_kind, abstract inputs) — no device
allocation, weak-type-correct, shardable.  The dry-run lowers the matching
step function against these.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import ops
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.param import shape_tree
from repro.models.registry import build_model
from repro.train.state import state_specs

# decode-time encoder memory length for enc-dec (30s audio at 50 fps ~ 1500;
# rounded up to a shardable 4096)
ENCDEC_DECODE_SRC_LEN = 4096
# prefill cell: decoder prompt is 1 BOS token; self cache sized small
ENCDEC_PREFILL_SELF_CACHE = 1024


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training batch stand-ins."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        # patch prefix + text tokens sum to the cell's seq_len
        text = s - cfg.num_patches
        out["tokens"] = _sds((b, text), jnp.int32)
        out["labels"] = _sds((b, text), jnp.int32)
        out["patch_embeds"] = _sds((b, cfg.num_patches, cfg.frontend_dim), jnp.float32)
    elif cfg.family == "encdec":
        out["tokens"] = _sds((b, s), jnp.int32)
        out["labels"] = _sds((b, s), jnp.int32)
        out["src_embeds"] = _sds((b, s, cfg.frontend_dim or cfg.d_model), jnp.float32)
    else:
        out["tokens"] = _sds((b, s), jnp.int32)
        out["labels"] = _sds((b, s), jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[str, Dict[str, Any]]:
    """Returns (step_kind, {name: abstract value}) for the cell."""
    # Capability-check the config's op specs before building anything: a
    # backend the registry cannot serve should fail here, with the
    # registry's actionable error, not halfway through lowering.
    ops.validate(cfg.attention_spec)
    ops.validate(cfg.softmax_spec)
    model = build_model(cfg)
    pspecs = model.param_specs()
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        return "train", {
            "state": shape_tree(state_specs(pspecs)),
            "batch": batch_specs(cfg, shape),
        }

    params = shape_tree(pspecs)

    if shape.kind == "prefill":
        inputs: Dict[str, Any] = {"params": params}
        if cfg.family == "encdec":
            inputs["tokens"] = _sds((b, 1), jnp.int32)
            inputs["src_embeds"] = _sds((b, s, cfg.frontend_dim or cfg.d_model), jnp.float32)
            inputs["_max_len"] = ENCDEC_PREFILL_SELF_CACHE
        elif cfg.family == "vlm":
            inputs["tokens"] = _sds((b, s - cfg.num_patches), jnp.int32)
            inputs["patch_embeds"] = _sds((b, cfg.num_patches, cfg.frontend_dim), jnp.float32)
            inputs["_max_len"] = s + 1
        else:
            inputs["tokens"] = _sds((b, s), jnp.int32)
            inputs["_max_len"] = s + 1
        return "prefill", inputs

    # decode: one new token against a seq_len-deep cache
    if cfg.family == "encdec":
        cache = shape_tree(model.cache_spec(b, s, src_len=ENCDEC_DECODE_SRC_LEN))
    else:
        cache = shape_tree(model.cache_spec(b, s))
    return "decode", {
        "params": params,
        "cache": cache,
        "tokens": _sds((b, 1), jnp.int32),
    }
