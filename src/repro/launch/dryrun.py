import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh; record memory/cost analysis + roofline terms.

MUST be run as its own process (the two lines above lock jax to 512 host
devices before any other import — never set that flag globally).

  python -m repro.launch.dryrun --arch granite_8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402


def _probe_depths(cfg) -> tuple:
    """(d1, d2, full_layers) for the unrolled cost probes."""
    if cfg.family == "hybrid":
        period = len(cfg.block_pattern)
        return period, 2 * period, cfg.num_layers
    return 1, 2, cfg.num_layers


def _with_depth(cfg, k: int):
    import dataclasses

    if cfg.family == "encdec":
        return dataclasses.replace(cfg, num_layers=k, num_decoder_layers=k)
    return dataclasses.replace(cfg, num_layers=k)


def _run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Optional[str],
              rules_override: Optional[Dict[str, Any]] = None,
              tag: str = "", microbatches: int = 1,
              probes: bool = True, moments_dtype: str = "float32",
              cfg_overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    import jax
    from jax.sharding import NamedSharding

    from repro.configs import SHAPES, get_config
    from repro.core.scan_ctl import unroll_scans
    from repro.distributed.sharding import (
        DEFAULT_RULES, logical_to_pspec, param_shardings, use_mesh_rules,
    )
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import (
        active_param_count, collective_bytes, model_flops, roofline_terms,
    )
    from repro.launch.specs import input_specs
    from repro.models.param import count_params
    from repro.models.registry import build_model
    from repro.train.state import state_specs
    from repro.train.step import TrainConfig, make_train_step

    t_start = time.time()
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    n_params = count_params(build_model(cfg).param_specs())

    rules = dict(DEFAULT_RULES)
    # big models: spread FSDP across the pod axis too, or optimizer state
    # alone blows the 16 GB/chip budget (llama3-405b)
    if n_params * 10 / 256 > 13e9:
        rules["embed"] = ("pod", "data")
    if rules_override:
        rules.update({k: tuple(v) if isinstance(v, (list, tuple)) else (v,)
                      for k, v in rules_override.items()})

    def lower_and_compile(cfg_k):
        """Build the cell's step fn for cfg_k; lower + compile on the mesh."""
        model = build_model(cfg_k)
        pspecs = model.param_specs()
        kind, inputs = input_specs(cfg_k, shape)

        def shard_of(spec_tree):
            return param_shardings(spec_tree, rules, mesh)

        def batch_sharding(tree):
            def one(sds):
                axes = ["batch"] + [None] * (len(sds.shape) - 1)
                return NamedSharding(mesh, logical_to_pspec(axes, sds.shape, rules, mesh))
            return jax.tree.map(one, tree)

        with use_mesh_rules(mesh, rules):
            if kind == "train":
                from repro.optim.adamw import AdamWConfig
                tc = TrainConfig(microbatches=microbatches,
                                 adamw=AdamWConfig(moments_dtype=moments_dtype))
                step = make_train_step(model, tc)
                st_spec = state_specs(pspecs, tc.adamw)
                fn = jax.jit(
                    step,
                    in_shardings=(shard_of(st_spec), batch_sharding(inputs["batch"])),
                    out_shardings=(shard_of(st_spec), None),
                    donate_argnums=(0,),
                )
                from repro.models.param import shape_tree as _st
                args = (_st(st_spec), inputs["batch"])
            elif kind == "prefill":
                max_len = inputs.pop("_max_len")
                frontend_keys = [k for k in inputs if k not in ("params", "tokens")]

                def prefill_fn(params, tokens, *front):
                    kw = dict(zip(frontend_keys, front))
                    return model.prefill(params, tokens, max_len, **kw)

                fn = jax.jit(
                    prefill_fn,
                    in_shardings=(
                        shard_of(pspecs),
                        batch_sharding(inputs["tokens"]),
                        *(batch_sharding(inputs[k]) for k in frontend_keys),
                    ),
                )
                args = (inputs["params"], inputs["tokens"],
                        *(inputs[k] for k in frontend_keys))
            else:  # decode
                if cfg_k.family == "encdec":
                    from repro.launch.specs import ENCDEC_DECODE_SRC_LEN
                    cache_spec = model.cache_spec(
                        shape.global_batch, shape.seq_len, src_len=ENCDEC_DECODE_SRC_LEN
                    )
                else:
                    cache_spec = model.cache_spec(shape.global_batch, shape.seq_len)
                fn = jax.jit(
                    model.decode_step,
                    in_shardings=(
                        shard_of(pspecs), shard_of(cache_spec),
                        batch_sharding(inputs["tokens"]),
                    ),
                )
                args = (inputs["params"], inputs["cache"], inputs["tokens"])
            t0 = time.time()
            lowered = fn.lower(*args)
            compiled = lowered.compile()
            return kind, compiled, time.time() - t0

    def costs_of(compiled):
        out = {"flops": 0.0, "bytes": 0.0, "coll": 0.0, "coll_detail": {}}
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # jax <= 0.4.x: one dict per device
            ca = ca[0] if ca else {}
        out["flops"] = float(ca.get("flops", 0.0))
        out["bytes"] = float(ca.get("bytes accessed", 0.0))
        coll = collective_bytes(compiled.as_text())
        out["coll"] = float(coll["total"])
        out["coll_detail"] = coll
        return out

    # ---- full-depth scanned compile: memory truth + compile-health ----------
    kind, compiled, t_full = lower_and_compile(cfg)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "step": kind,
        "chips": chips, "ok": True, "tag": tag, "n_params": n_params,
        "compile_s": round(t_full, 2), "microbatches": microbatches,
    }
    try:
        ma = compiled.memory_analysis()
        for field in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes",
        ):
            rec[field] = int(getattr(ma, field, 0))
        rec["peak_bytes_per_dev"] = int(
            getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "temp_size_in_bytes", 0)
        )
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = str(e)
    scanned_costs = costs_of(compiled)
    rec["scanned_flops_per_dev"] = scanned_costs["flops"]  # undercounted (scan)
    del compiled

    # ---- unrolled depth probes: exact per-layer costs, extrapolated ----------
    # XLA cost_analysis counts a scan body once regardless of trip count, so
    # FLOPs/bytes/collectives come from unrolled d1/d2-layer probes:
    #   full = C1 + (L - d1)/(d2 - d1) * (C2 - C1)
    d1, d2, l_full = _probe_depths(cfg)
    if not probes:
        rec["flops_per_dev"] = scanned_costs["flops"]
        rec["bytes_per_dev"] = scanned_costs["bytes"]
        rec["coll_bytes_per_dev"] = scanned_costs["coll"]
        rec["collectives"] = scanned_costs["coll_detail"]
        rec["probes"] = False
    elif True:
      try:
        with unroll_scans():
            _, c1, t1 = lower_and_compile(_with_depth(cfg, d1))
            p1 = costs_of(c1)
            del c1
            _, c2, t2 = lower_and_compile(_with_depth(cfg, d2))
            p2 = costs_of(c2)
            del c2
        scale = (l_full - d1) / (d2 - d1)
        rec["probe_compile_s"] = round(t1 + t2, 2)
        rec["flops_per_dev"] = p1["flops"] + scale * (p2["flops"] - p1["flops"])
        rec["bytes_per_dev"] = p1["bytes"] + scale * (p2["bytes"] - p1["bytes"])
        rec["coll_bytes_per_dev"] = p1["coll"] + scale * (p2["coll"] - p1["coll"])
        by1 = p1["coll_detail"]["by_op"]
        by2 = p2["coll_detail"]["by_op"]
        rec["collectives"] = {
            "by_op": {
                op: int(by1.get(op, 0) + scale * (by2.get(op, 0) - by1.get(op, 0)))
                for op in set(by1) | set(by2)
            },
            "count_probe_d2": p2["coll_detail"]["count"],
        }
      except Exception as e:  # pragma: no cover
        rec["probe_error"] = str(e)[-2000:]
        rec["flops_per_dev"] = scanned_costs["flops"]
        rec["bytes_per_dev"] = scanned_costs["bytes"]
        rec["coll_bytes_per_dev"] = scanned_costs["coll"]
        rec["collectives"] = scanned_costs["coll_detail"]

    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    n_active = active_param_count(cfg, build_model(cfg).param_specs())
    rec["model_flops_global"] = model_flops(n_params, n_active, tokens, kind)
    rec["hlo_flops_global"] = rec["flops_per_dev"] * chips
    rec["useful_flops_ratio"] = (
        rec["model_flops_global"] / rec["hlo_flops_global"]
        if rec["hlo_flops_global"] else 0.0
    )
    rec.update(
        roofline_terms(
            flops_per_dev=rec["flops_per_dev"],
            bytes_per_dev=rec["bytes_per_dev"],
            coll_bytes_per_dev=rec["coll_bytes_per_dev"],
        )
    )
    rec["wall_s"] = round(time.time() - t_start, 2)

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_kind}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def _driver(mesh_kinds, out_dir: str, archs=None, shapes=None) -> int:
    """Run every cell in a fresh subprocess (isolates compile memory)."""
    from repro.configs import all_cells

    cells = all_cells()
    if archs:
        cells = [c for c in cells if c[0] in archs]
    if shapes:
        cells = [c for c in cells if c[1] in shapes]
    failures = []
    for mesh_kind in mesh_kinds:
        for arch, shape in cells:
            suffix = os.path.join(out_dir, f"{arch}_{shape}_{mesh_kind}.json")
            if os.path.exists(suffix):
                print(f"[dryrun] skip cached {arch} x {shape} x {mesh_kind}")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                "--out", out_dir,
            ]
            if mesh_kind == "multi":
                cmd.append("--no-probes")  # roofline table is single-pod only
            print(f"[dryrun] {arch} x {shape} x {mesh_kind} ...", flush=True)
            try:
                r = subprocess.run(cmd, capture_output=True, text=True, timeout=2400)
            except subprocess.TimeoutExpired as te:
                class _R:  # noqa
                    returncode = 1
                    stdout = (te.stdout or b"").decode() if isinstance(te.stdout, bytes) else (te.stdout or "")
                    stderr = "TIMEOUT after 2400s"
                r = _R()
            if r.returncode != 0:
                failures.append((arch, shape, mesh_kind))
                err_path = os.path.join(out_dir, f"{arch}_{shape}_{mesh_kind}.err")
                os.makedirs(out_dir, exist_ok=True)
                with open(err_path, "w") as f:
                    f.write(r.stdout[-4000:] + "\n" + r.stderr[-8000:])
                print(f"[dryrun]   FAILED (see {err_path})")
            else:
                print(r.stdout.strip().splitlines()[-1] if r.stdout.strip() else "ok")
    print(f"[dryrun] done; {len(failures)} failures: {failures}")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rules", default=None, help="JSON logical-rule overrides")
    ap.add_argument("--tag", default="", help="suffix for perf-iteration records")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--moments-dtype", default="float32")
    ap.add_argument("--cfg", default=None, help="JSON ModelConfig field overrides")
    args = ap.parse_args()

    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        return _driver(mesh_kinds, args.out or "experiments/dryrun",
                       archs=args.arch.split(",") if args.arch else None,
                       shapes=args.shape.split(",") if args.shape else None)

    overrides = json.loads(args.rules) if args.rules else None
    for mk in mesh_kinds:
        try:
            rec = _run_cell(args.arch, args.shape, mk, args.out, overrides, args.tag,
                            microbatches=args.microbatches,
                            probes=not args.no_probes,
                            moments_dtype=args.moments_dtype,
                            cfg_overrides=json.loads(args.cfg) if args.cfg else None)
            print(json.dumps(
                {k: rec[k] for k in (
                    "arch", "shape", "mesh", "chips", "flops_per_dev",
                    "bytes_per_dev", "coll_bytes_per_dev", "t_compute_s",
                    "t_memory_s", "t_collective_s", "dominant",
                    "peak_bytes_per_dev", "useful_flops_ratio", "compile_s",
                ) if k in rec}
            ))
        except Exception:
            traceback.print_exc()
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
