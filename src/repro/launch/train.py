"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite_8b --smoke \\
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On real TPU fleets: one process per host, jax.distributed.initialize()
first (flag --multihost), then the same code path — the mesh spans all
pods.  XLA latency-hiding flags for collective/compute overlap are set
here (no-ops on CPU).
"""

from __future__ import annotations

import argparse
import os
import sys

TPU_PERF_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default=None, help="e.g. '4,2' => (data,model)")
    ap.add_argument("--multihost", action="store_true")
    args = ap.parse_args()

    if args.multihost:  # pragma: no cover - needs a real fleet
        os.environ.setdefault("XLA_FLAGS", TPU_PERF_FLAGS)
        import jax

        jax.distributed.initialize()
    import jax

    from repro.configs import get_config, get_smoke_config
    from repro.launch.mesh import make_mesh
    from repro.train.loop import LoopConfig, run_train
    from repro.train.step import TrainConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        axes = ("data", "model")[: len(shape)] if len(shape) <= 2 else ("pod", "data", "model")
        mesh = make_mesh(shape, axes)

    res = run_train(
        cfg,
        TrainConfig(peak_lr=args.lr, total_steps=args.steps, warmup_steps=max(1, args.steps // 10), microbatches=args.microbatches),
        LoopConfig(
            num_steps=args.steps, batch=args.batch, seq_len=args.seq,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        ),
        mesh=mesh,
    )
    print(f"final loss: {res['history'][-1]['loss']:.4f} after {res['final_step']} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
