"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), all in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_per_dev / PEAK_FLOPS
  memory     = HLO_bytes_per_dev / HBM_BW
  collective = collective_bytes_per_dev / LINK_BW

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (per-partition for
SPMD modules).  Collective bytes are NOT in cost_analysis: we parse the
post-partitioning optimized HLO (``compiled.as_text()``) and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per-partition shapes -> per-device bytes).
"""

from __future__ import annotations

import re
from typing import Any, Dict

import numpy as np

# TPU v5e
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

# "%name = TYPE opcode(...)" where TYPE is e.g. f32[8,128]{1,0} or a tuple
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)\)",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes of every collective op in (partitioned) HLO text.

    Returns {"total": int, "by_op": {op: bytes}, "count": {op: n}}.
    Operand sizes are resolved via a symbol table of instruction result
    types; literals/params inline in operand lists are rare for collectives.
    """
    symbols: Dict[str, str] = {}
    instrs = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, operands = m.groups()
        symbols[name] = type_str
        instrs.append((name, type_str, opcode, operands))

    by_op: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    count: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for name, type_str, opcode, operands in instrs:
        base = None
        for op in COLLECTIVE_OPS:
            if opcode == op or opcode.startswith(op + "-"):  # e.g. all-gather-start
                base = op
                break
        if base is None:
            continue
        if opcode.endswith("-done"):
            continue  # paired with -start; avoid double count
        # operand references: %name or plain name tokens before any attrs
        ops_bytes = 0
        for ref in re.findall(r"%?([\w\.\-]+)", operands.split("),")[0]):
            if ref in symbols:
                ops_bytes += _shape_bytes(symbols[ref])
        if ops_bytes == 0:
            # fall back to result size (e.g. operands not in table)
            ops_bytes = _shape_bytes(type_str)
        by_op[base] += ops_bytes
        count[base] += 1
    return {
        "total": int(sum(by_op.values())),
        "by_op": {k: int(v) for k, v in by_op.items() if v},
        "count": {k: int(v) for k, v in count.items() if v},
    }


def model_flops(n_params: int, n_active_params: int, tokens: int, kind: str) -> float:
    """6*N*D for training, 2*N*D for inference forward (N = active params)."""
    n = n_active_params or n_params
    return (6.0 if kind == "train" else 2.0) * n * tokens


def roofline_terms(
    *,
    flops_per_dev: float,
    bytes_per_dev: float,
    coll_bytes_per_dev: float,
) -> Dict[str, float]:
    t_c = flops_per_dev / PEAK_FLOPS
    t_m = bytes_per_dev / HBM_BW
    t_x = coll_bytes_per_dev / LINK_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    bound = max(t_c, t_m, t_x)
    return {
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_x,
        "dominant": dominant,
        "roofline_fraction": (t_c / bound) if bound > 0 else 0.0,
    }


def active_param_count(cfg, pspecs) -> int:
    """Active params per token (MoE: only top_k experts count)."""
    from repro.models.param import count_params

    total = count_params(pspecs)
    if cfg.family != "moe" or cfg.num_experts == 0:
        return total
    # expert weights: [E, d, f] x3 per layer
    expert_per_layer = 3 * cfg.num_experts * cfg.d_model * cfg.d_ff
    expert_total = cfg.num_layers * expert_per_layer
    active_expert = expert_total * cfg.top_k / cfg.num_experts
    return int(total - expert_total + active_expert)
