"""Serving launcher: lockstep batch or continuous-batching generation.

  # lockstep (one fixed batch, synchronized decode)
  PYTHONPATH=src python -m repro.launch.serve --arch granite_8b --smoke \\
      --batch 4 --prompt-len 32 --gen 16

  # continuous batching (slot pool, staggered mixed-length requests)
  PYTHONPATH=src python -m repro.launch.serve --arch granite_8b --smoke \\
      --engine continuous --requests 8 --slots 4 --gen 16

  # paged KV cache (block-pool allocator; DESIGN.md §8)
  PYTHONPATH=src python -m repro.launch.serve --arch granite_8b --smoke \\
      --engine continuous --attn-impl paged --kv-block-size 16

  # shared-prefix KV cache + chunked prefill (DESIGN.md §12)
  PYTHONPATH=src python -m repro.launch.serve --arch granite_8b --smoke \\
      --engine continuous --kv-layout paged --prefix-cache \\
      --prefill-chunk-tokens 8

  # observability (DESIGN.md §10): Chrome trace + metrics snapshot
  PYTHONPATH=src python -m repro.launch.serve --arch granite_8b --smoke \\
      --engine continuous --trace-out trace.json --metrics-out metrics.json

Backend selection goes through the ``repro.ops`` registry: the config's
specs pick the defaults, ``--attn-impl`` / ``--softmax-impl`` retarget
every dispatch via ``ops.use(...)``, and Pallas interpret-vs-compile is
the platform's choice (``ops.default_interpret``) — the launcher no
longer flips any kernel flag by hand.

``--trace-out`` enables the global tracer for the run and writes the
Chrome trace-event JSON at exit (load it at https://ui.perfetto.dev);
``--metrics-out`` writes the merged metrics snapshot (engine registry +
process-global dispatch/guard counters).
"""

from __future__ import annotations

import argparse
import sys
import time


def _frontend_kwargs(cfg, rng, batch):
    import jax.numpy as jnp

    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.num_patches, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "encdec":
        kw["src_embeds"] = jnp.asarray(
            rng.standard_normal((batch, 64, cfg.frontend_dim or cfg.d_model)), jnp.float32)
    return kw


def _write_obs(args, engine=None) -> None:
    """Export the Chrome trace and/or metrics snapshot when requested."""
    import json

    from repro import obs

    if args.trace_out:
        tracer = obs.get_tracer()
        tracer.export_chrome(args.trace_out)
        print(f"wrote {len(tracer.events)} trace events to {args.trace_out} "
              f"(load in https://ui.perfetto.dev)")
    if args.metrics_out:
        snap = {"global": obs.default_registry().snapshot()}
        if engine is not None:
            snap["engine"] = engine.stats()
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=2, default=float)
        print(f"wrote metrics snapshot to {args.metrics_out}")


def run_lockstep(args, cfg, params) -> int:
    import jax.numpy as jnp
    import numpy as np

    from repro import obs
    from repro.serve.engine import ServeConfig, ServeEngine

    max_len = args.max_len or (args.prompt_len + args.gen + cfg.num_patches + 8)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=max_len, temperature=args.temperature))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    kw = _frontend_kwargs(cfg, rng, args.batch)

    t0 = time.perf_counter()
    with obs.get_tracer().span("serve.generate", batch=args.batch, gen=args.gen):
        toks, info = eng.generate(prompts, args.gen, **kw)
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s) cache_len={info['cache_len']}")
    print("sample:", np.asarray(toks[0])[:16].tolist())
    _write_obs(args)
    return 0


def run_continuous(args, cfg, params) -> int:
    import numpy as np

    from repro.serve.engine import ContinuousBatchingEngine, ContinuousConfig

    max_len = args.max_len or (args.prompt_len + args.gen + cfg.num_patches + 8)
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=args.slots, max_len=max_len,
                         temperature=args.temperature,
                         kv_layout=args.kv_layout,
                         kv_block_size=args.kv_block_size,
                         kv_pool_blocks=args.kv_pool_blocks,
                         kv_dtype=args.kv_dtype,
                         prefix_cache=args.prefix_cache,
                         prefill_chunk_tokens=args.prefill_chunk_tokens),
    )
    rng = np.random.default_rng(0)
    total = 0
    for i in range(args.requests):
        # mixed-length traffic: vary prompt and generation budgets
        plen = max(1, int(rng.integers(args.prompt_len // 2, args.prompt_len + 1)))
        gen = max(1, int(rng.integers(args.gen // 2, args.gen + 1)))
        kw = {}
        if cfg.family == "vlm":  # per-request stub patch embeddings
            kw["patch_embeds"] = rng.standard_normal(
                (1, cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
        eng.submit(rng.integers(0, cfg.vocab_size, (plen,)), gen, **kw)
        total += gen

    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s) over {eng.ticks} decode ticks "
          f"({args.slots} slots, kv={eng.kv_layout})")
    if eng.kv_layout == "paged":
        st = eng.kv_stats()
        print(f"paged kv: peak {st['peak_used_blocks']}/{st['total_blocks']} "
              f"blocks ({st['peak_kv_bytes'] / 1e6:.2f} MB), "
              f"{st['preemptions']} preemptions")
        if st.get("prefix") is not None:
            p = st["prefix"]
            print(f"prefix cache: {p['hits']} hits, "
                  f"{p['tokens_saved']} prefill tokens saved, "
                  f"{p['evicted']} evicted ({p['nodes']} trie nodes)")
    lat = eng.metrics.histogram("serve.ttft_s")
    if lat.count():
        print(f"ttft p50={1e3 * lat.percentile(50):.1f}ms "
              f"p95={1e3 * lat.percentile(95):.1f}ms (n={lat.count()})")
    first = done[min(done)]
    print("sample:", first[:16])
    _write_obs(args, eng)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("lockstep", "continuous"), default="lockstep")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8, help="continuous: request count")
    ap.add_argument("--slots", type=int, default=4, help="continuous: KV slot pool size")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument(
        "--attn-impl", default=None, metavar="IMPL",
        help="force an attention backend (registry impl: reference|xla|pallas; "
        "'paged' additionally flips the continuous engine to the block-pool "
        "KV cache; 'pallas_paged' selects the block-pool cache AND routes "
        "decode through the gather-free scalar-prefetch kernel)",
    )
    ap.add_argument(
        "--kv-layout", choices=("dense", "paged"), default="dense",
        help="continuous-engine KV cache layout (--attn-impl paged also "
        "selects 'paged' via the ops override)",
    )
    ap.add_argument(
        "--kv-block-size", type=int, default=16,
        help="paged KV: tokens per cache block",
    )
    ap.add_argument(
        "--kv-pool-blocks", type=int, default=None,
        help="paged KV: usable blocks in the pool (default: dense-equivalent "
        "capacity slots * ceil(cache_len / block_size), where cache_len is "
        "max_len clamped to the arch's sliding window)",
    )
    ap.add_argument(
        "--kv-dtype", choices=("fp32", "int8", "fp8_e4m3"), default="fp32",
        help="paged KV: page-pool storage layout — int8/fp8_e4m3 store "
        "quantized codes plus per-(block, head) scale pages and decode "
        "dequantizes in-kernel (DESIGN.md §13); requires --kv-layout paged "
        "(or --attn-impl paged/pallas_paged)",
    )
    ap.add_argument(
        "--prefix-cache", action="store_true",
        help="continuous+paged: share KV blocks across requests with a "
        "common prompt prefix (radix trie over token-id block chunks; "
        "admission skips prefill for the cached prefix — DESIGN.md §12)",
    )
    ap.add_argument(
        "--prefill-chunk-tokens", type=int, default=None,
        help="continuous: budget of prompt tokens prefilled per tick; "
        "prompts stream through in power-of-two chunks interleaved with "
        "decode instead of head-of-line-blocking the pool",
    )
    ap.add_argument(
        "--softmax-impl", default=None, metavar="IMPL",
        help="force a softmax backend (registry impl: reference|xla|pallas)",
    )
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="enable tracing for the run and write Chrome trace-event JSON "
        "here (view at https://ui.perfetto.dev)",
    )
    ap.add_argument(
        "--metrics-out", default=None, metavar="PATH",
        help="write the metrics snapshot (engine registry + global "
        "dispatch/guard counters) as JSON",
    )
    args = ap.parse_args()

    import jax

    from repro import obs, ops

    if args.trace_out:
        # install before the engine is built — engines bind the global
        # tracer at construction
        obs.enable_tracing()
    from repro.configs import get_config, get_smoke_config
    from repro.models.param import materialize
    from repro.models.registry import build_model

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    attn_impl = args.attn_impl
    overrides = {}
    if attn_impl == "pallas_paged":
        # the gather-free paged decode kernel (DESIGN.md §11): flip the
        # serve stack to the block-pool cache and retarget the paged op;
        # dense invocations (prefill, lockstep) keep the marker's xla math
        ops.validate(
            cfg.paged_attention_spec, impl="pallas_paged",
            kv_dtype=args.kv_dtype,
        )
        overrides["paged_attention"] = "pallas_paged"
        attn_impl = "paged"
    elif args.kv_dtype != "fp32":
        # quantized pages: fail at config time if the resolved paged
        # backend cannot dequantize this layout (DESIGN.md §13)
        ops.validate(cfg.paged_attention_spec, kv_dtype=args.kv_dtype)
    # fail fast on a spec the registry cannot serve, before any lowering
    ops.validate(cfg.attention_spec, impl=attn_impl or cfg.attention_spec.impl)
    ops.validate(cfg.softmax_spec, impl=args.softmax_impl or cfg.softmax_spec.impl)

    if attn_impl:
        overrides["attention"] = attn_impl
    if args.softmax_impl:
        overrides["softmax"] = args.softmax_impl
    with ops.use(**overrides):
        model = build_model(cfg)
        params = materialize(model.param_specs(), jax.random.PRNGKey(0))
        if args.engine == "continuous":
            return run_continuous(args, cfg, params)
        return run_lockstep(args, cfg, params)


if __name__ == "__main__":
    sys.exit(main())
