"""Serving launcher: batched generation with the STAR engine.

  PYTHONPATH=src python -m repro.launch.serve --arch granite_8b --smoke \\
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--max-len", type=int, default=None)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_smoke_config
    from repro.models.registry import build_model
    from repro.models.param import materialize
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = materialize(model.param_specs(), jax.random.PRNGKey(0))
    max_len = args.max_len or (args.prompt_len + args.gen + cfg.num_patches + 8)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=max_len, temperature=args.temperature))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_patches, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "encdec":
        kw["src_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, 64, cfg.frontend_dim or cfg.d_model)), jnp.float32)

    t0 = time.perf_counter()
    toks, info = eng.generate(prompts, args.gen, **kw)
    dt = time.perf_counter() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s) cache_len={info['cache_len']}")
    print("sample:", np.asarray(toks[0])[:16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
