"""Serving engines: lockstep batch generation and continuous batching.

Two engines share the model, the KV-cache machinery, and STAR-softmax
sampling (temperature folded into the logits before quantization — the
paper's precision argument applies to the output distribution too):

* :class:`ServeEngine` — the lockstep baseline: one fixed batch prefills
  together, decodes together, finishes together.  Simple, and the right
  tool when every request has the same shape; pathological under
  heterogeneous traffic, where the whole batch waits for its longest
  member.

* :class:`ContinuousBatchingEngine` — a slot-pool engine (the tentpole).
  Requests are admitted into a fixed pool of KV-cache slots as they arrive
  (``SlotScheduler`` handles the lifecycle: FIFO admission, backpressure
  when the pool is full, immediate slot reuse on completion).  Every tick
  runs **one** jitted ``decode_step`` across the whole pool; per-slot
  ``len``/``pos`` vectors in the cache (see ``DecoderLM.init_pool_cache``
  and the per-slot path in ``layers.attention_block``) let each slot attend
  at its own depth, so a newly admitted 8-token prompt and a 400-token
  veteran decode side by side in the same MXU pass.  This is the paper's
  fine-grained pipeline argument lifted to the request level: throughput
  comes from never letting a lane idle.

Slot lifecycle (one ``step()`` tick)::

    admit:   pending ──> free slot: prefill(batch=1) -> write_slot(pool)
                          sample token 0 from the prefill logits
    decode:  one jitted decode_step over all S slots  [S,1] -> [S,1,V]
             sample token t per active slot
    retire:  finished slots (budget / EOS) release immediately;
             reset_slot zeroes the slot's counters (stale rows masked;
             free-slot counters regrow with the pool-wide tick — the
             scheduler, not len, is the source of truth for occupancy)

Greedy continuous-batching output is bit-identical to sequential
``ServeEngine.generate`` calls for the same prompts (tests/test_serve.py);
with temperature, each request gets its own PRNG stream (folded from its
uid), so sampled output is also independent of pool co-tenancy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.configs.base import ModelConfig
from repro.models.registry import build_model
from repro.models.transformer import DecoderLM
from repro.serve.scheduler import Request, Slot, SlotScheduler

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0  # 0 = greedy
    star_sampling: bool = True  # STAR softmax on the output distribution


def sample_token(
    logits: jax.Array,  # [..., V]
    key: jax.Array,
    cfg: ModelConfig,
    serve_cfg: ServeConfig,
) -> jax.Array:
    """Greedy or temperature sampling, through the STAR engine when
    configured (the quantized LUT softmax shapes the sampling distribution
    exactly like it shapes attention rows)."""
    t = serve_cfg.temperature
    if t <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / t
    spec = cfg.softmax_spec
    if serve_cfg.star_sampling and spec.kind != "exact":
        probs = ops.softmax(scaled, spec)
        return jax.random.categorical(
            key, jnp.log(jnp.maximum(probs, 1e-20)), axis=-1
        ).astype(jnp.int32)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


class ServeEngine:
    """Lockstep batch engine: one prefill, then synchronized decode."""

    def __init__(self, model_cfg: ModelConfig, params: PyTree, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = model_cfg
        self.params = params
        self.serve_cfg = serve_cfg
        self.model = build_model(model_cfg)
        self._decode = jax.jit(self.model.decode_step)

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        return sample_token(logits, key, self.cfg, self.serve_cfg)

    def generate(
        self,
        prompts: jax.Array,  # [B, T] token prompts
        num_tokens: int,
        *,
        key: Optional[jax.Array] = None,
        **frontend,  # patch_embeds / src_embeds stubs
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        key = key if key is not None else jax.random.PRNGKey(0)
        b, t = prompts.shape
        max_len = self.serve_cfg.max_len
        logits, cache = self.model.prefill(self.params, prompts, max_len, **frontend)
        outs = []
        tok = self._sample(logits[:, -1], key)[:, None]
        outs.append(tok)
        for i in range(num_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits[:, -1], sub)[:, None]
            outs.append(tok)
        generated = jnp.concatenate(outs, axis=1)
        return generated, {"cache_len": int(jax.device_get(cache["len"]))}


# ---------------------------------------------------------------------------
# Continuous batching


@dataclasses.dataclass
class ContinuousConfig:
    num_slots: int = 8  # KV-cache pool size (max concurrent requests)
    max_len: int = 512  # per-slot cache capacity (prompt + generation)
    temperature: float = 0.0
    star_sampling: bool = True

    def as_serve_config(self) -> ServeConfig:
        return ServeConfig(self.max_len, self.temperature, self.star_sampling)


@dataclasses.dataclass
class TokenEvent:
    """One emitted token: streamed to ``on_token`` and returned by step()."""

    uid: int
    token: int
    index: int  # 0-based position within the request's generation
    finished: bool


class ContinuousBatchingEngine:
    """Slot-pool serving: admit, decode the whole pool per tick, retire.

    Host-side control (the :class:`SlotScheduler`) decides *which* requests
    occupy which slots; the device-side tick is a single jitted
    ``decode_step`` over the ``[num_slots, 1]`` token matrix.  Free slots
    decode garbage that is masked (their ``len`` counter is 0) and simply
    discarded — the fixed shape is what keeps the step jit-stable.
    """

    def __init__(
        self,
        model_cfg: ModelConfig,
        params: PyTree,
        cb_cfg: ContinuousConfig = ContinuousConfig(),
        *,
        base_key: Optional[jax.Array] = None,
        on_token: Optional[Callable[[TokenEvent], None]] = None,
    ):
        self.cfg = model_cfg
        self.params = params
        self.cb = cb_cfg
        self.model = build_model(model_cfg)
        if not isinstance(self.model, DecoderLM):
            raise ValueError(
                "continuous batching needs the per-slot KV-cache pool, which "
                f"only attention-family models implement (got {model_cfg.family!r})"
            )
        self.scheduler = SlotScheduler(cb_cfg.num_slots)
        self.pool = self.model.init_pool_cache(cb_cfg.num_slots, cb_cfg.max_len)
        # donate the pool everywhere it is threaded through: the tick, the
        # admission write, and the retirement reset all update it in place
        # instead of copying the whole [L, S, T, H, D] pool (self.pool is
        # rebound to the result each call, so the old buffer is never live)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._write_slot = jax.jit(
            self.model.write_slot, static_argnums=(2,), donate_argnums=(0,))
        self._reset_slot = jax.jit(
            self.model.reset_slot, static_argnums=(1,), donate_argnums=(0,))
        self._serve_cfg = cb_cfg.as_serve_config()
        self._base_key = base_key if base_key is not None else jax.random.PRNGKey(0)
        self._on_token = on_token
        self._inputs = np.zeros((cb_cfg.num_slots, 1), np.int32)  # next token per slot
        self._frontend: Dict[int, Dict[str, jax.Array]] = {}
        self.ticks = 0  # decode ticks executed (for utilization accounting)

    # -- submission ---------------------------------------------------------

    def submit(
        self,
        prompt: Sequence[int] | np.ndarray,
        max_new_tokens: int,
        *,
        eos_id: Optional[int] = None,
        arrival_time: float = 0.0,
        **frontend,
    ) -> int:
        """Queue a request (never blocks); returns its uid."""
        if self.cfg.sliding_window is None:
            # decode writes prompt + (max_new_tokens - 1) K/V rows (the last
            # sampled token is never fed back); past capacity the per-slot
            # write would silently drop rows, so reject up front
            prefix = self.cfg.num_patches if (
                self.cfg.family == "vlm" and "patch_embeds" in frontend) else 0
            need = prefix + len(prompt) + max_new_tokens - 1
            if need > self.cb.max_len:
                raise ValueError(
                    f"request needs {need} cache rows (prompt {len(prompt)} "
                    f"+ prefix {prefix} + {max_new_tokens} new tokens) but "
                    f"the pool was built with max_len={self.cb.max_len}"
                )
        uid = self.scheduler.submit(
            prompt, max_new_tokens, eos_id=eos_id, arrival_time=arrival_time
        )
        if frontend:
            self._frontend[uid] = {k: jnp.asarray(v) for k, v in frontend.items()}
        return uid

    # -- the tick -----------------------------------------------------------

    def _request_key(self, req: Request, index: int) -> jax.Array:
        # Per-request stream, independent of slot placement and co-tenants.
        return jax.random.fold_in(jax.random.fold_in(self._base_key, req.uid), index)

    def _emit(self, slot: Slot, token: int, finished: bool) -> TokenEvent:
        req = slot.request
        ev = TokenEvent(req.uid, token, len(slot.generated) - 1, finished)
        if self._on_token is not None:
            self._on_token(ev)
        return ev

    def _finish(self, slot: Slot) -> None:
        req = self.scheduler.retire(slot)
        self._frontend.pop(req.uid, None)
        self.pool = self._reset_slot(self.pool, slot.index)

    def step(self) -> List[TokenEvent]:
        """One engine tick: admit + prefill new requests, then one jitted
        decode across the pool.  Returns the tokens emitted this tick."""
        events: List[TokenEvent] = []

        # 1. admission: prefill pending requests into free slots.  Decode
        #    state of already-active slots is untouched — they proceed on
        #    the same tick below.
        for slot in self.scheduler.admit():
            req = slot.request
            fe = self._frontend.get(req.uid, {})
            logits, cache1 = self.model.prefill(
                self.params, jnp.asarray(req.prompt)[None], self.cb.max_len, **fe
            )
            self.pool = self._write_slot(self.pool, cache1, slot.index)
            tok = int(sample_token(
                logits[0, -1], self._request_key(req, 0), self.cfg, self._serve_cfg
            ))
            finished = self.scheduler.record_token(slot, tok)
            events.append(self._emit(slot, tok, finished))
            self._inputs[slot.index, 0] = tok
            if finished:
                self._finish(slot)

        # 2. one decode tick across the whole slot pool.
        active = self.scheduler.active_slots
        if active:
            logits, self.pool = self._decode(
                self.params, self.pool, jnp.asarray(self._inputs)
            )
            last = logits[:, -1]  # [S, V]
            # one batched sampling program + one host sync for all slots
            if self._serve_cfg.temperature <= 0.0:
                sampled = np.asarray(jnp.argmax(last, axis=-1))
                toks = {s.index: int(sampled[s.index]) for s in active}
            else:
                rows = jnp.asarray([s.index for s in active])
                uids = jnp.asarray([s.request.uid for s in active])
                steps = jnp.asarray([len(s.generated) for s in active])
                keys = jax.vmap(lambda u, i: jax.random.fold_in(
                    jax.random.fold_in(self._base_key, u), i))(uids, steps)
                sampled = np.asarray(jax.vmap(
                    lambda lg, k: sample_token(lg, k, self.cfg, self._serve_cfg)
                )(last[rows], keys))
                toks = {s.index: int(t) for s, t in zip(active, sampled)}
            for slot in active:
                tok = toks[slot.index]
                finished = self.scheduler.record_token(slot, tok)
                events.append(self._emit(slot, tok, finished))
                self._inputs[slot.index, 0] = tok
                if finished:
                    self._finish(slot)
            self.ticks += 1
        return events

    # -- draining -----------------------------------------------------------

    def run(self, max_ticks: Optional[int] = None) -> Dict[int, List[int]]:
        """Drive ticks until every submitted request has finished; returns
        {uid: generated tokens}."""
        n = 0
        while not self.scheduler.done():
            self.step()
            n += 1
            if max_ticks is not None and n >= max_ticks and not self.scheduler.done():
                raise RuntimeError(f"engine did not drain within {max_ticks} ticks")
        return dict(self.scheduler.finished)

    def serve(
        self,
        prompts: Sequence[Sequence[int] | np.ndarray],
        max_new_tokens: int | Sequence[int],
        *,
        eos_id: Optional[int] = None,
    ) -> List[List[int]]:
        """Convenience: submit all prompts, drain, return outputs in order."""
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * len(prompts)
        uids = [
            self.submit(p, int(m), eos_id=eos_id)
            for p, m in zip(prompts, max_new_tokens)
        ]
        done = self.run()
        return [done[u] for u in uids]
