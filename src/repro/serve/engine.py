"""Batched serving engine: prefill + decode with STAR-softmax sampling.

The final sampling softmax also runs through the STAR engine (temperature
folded into the logits before quantization) — the paper's precision
argument applies to the output distribution too.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.star_softmax import star_softmax
from repro.models.registry import build_model

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0  # 0 = greedy
    star_sampling: bool = True  # STAR softmax on the output distribution


class ServeEngine:
    def __init__(self, model_cfg: ModelConfig, params: PyTree, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = model_cfg
        self.params = params
        self.serve_cfg = serve_cfg
        self.model = build_model(model_cfg)
        self._decode = jax.jit(self.model.decode_step)

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        t = self.serve_cfg.temperature
        if t <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits.astype(jnp.float32) / t
        if self.serve_cfg.star_sampling and self.cfg.softmax_kind != "exact":
            probs = star_softmax(
                scaled, self.cfg.softmax_format, mode=self.cfg.softmax_mode
            )
            return jax.random.categorical(key, jnp.log(jnp.maximum(probs, 1e-20)), axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)

    def generate(
        self,
        prompts: jax.Array,  # [B, T] token prompts
        num_tokens: int,
        *,
        key: Optional[jax.Array] = None,
        **frontend,  # patch_embeds / src_embeds stubs
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        key = key if key is not None else jax.random.PRNGKey(0)
        b, t = prompts.shape
        max_len = self.serve_cfg.max_len
        logits, cache = self.model.prefill(self.params, prompts, max_len, **frontend)
        outs = []
        tok = self._sample(logits[:, -1], key)[:, None]
        outs.append(tok)
        for i in range(num_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits[:, -1], sub)[:, None]
            outs.append(tok)
        generated = jnp.concatenate(outs, axis=1)
        return generated, {"cache_len": int(jax.device_get(cache["len"]))}
