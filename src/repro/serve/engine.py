"""Serving engines: lockstep batch generation and continuous batching.

Two engines share the model, the KV-cache machinery, and STAR-softmax
sampling (temperature folded into the logits before quantization — the
paper's precision argument applies to the output distribution too):

* :class:`ServeEngine` — the lockstep baseline: one fixed batch prefills
  together, decodes together, finishes together.  Simple, and the right
  tool when every request has the same shape; pathological under
  heterogeneous traffic, where the whole batch waits for its longest
  member.

* :class:`ContinuousBatchingEngine` — a slot-pool engine (the tentpole).
  Requests are admitted into a fixed pool of KV-cache slots as they arrive
  (``SlotScheduler`` handles the lifecycle: FIFO admission, backpressure
  when the pool is full, immediate slot reuse on completion).  Every tick
  runs **one** jitted ``decode_step`` across the whole pool; per-slot
  ``len``/``pos`` vectors in the cache (see ``DecoderLM.init_pool_cache``
  and the per-slot path in ``layers.attention_block``) let each slot attend
  at its own depth, so a newly admitted 8-token prompt and a 400-token
  veteran decode side by side in the same MXU pass.  This is the paper's
  fine-grained pipeline argument lifted to the request level: throughput
  comes from never letting a lane idle.

Slot lifecycle (one ``step()`` tick)::

    admit:   pending ──> free slot: prefill(batch=1) -> write_slot(pool)
                          sample token 0 from the prefill logits
    decode:  one jitted decode_step over all S slots  [S,1] -> [S,1,V]
             sample token t per active slot
    retire:  finished slots (budget / EOS) release immediately;
             reset_slot zeroes the slot's counters (stale rows masked;
             free-slot counters regrow with the pool-wide tick — the
             scheduler, not len, is the source of truth for occupancy)

The engine offers two KV layouts (``ContinuousConfig.kv_layout``): the
dense per-slot pool above, and the **paged** block-pool cache (DESIGN.md
§8, ``serve/paged.py``) where admission allocates fixed-size token blocks,
decode appends blocks as slots cross block boundaries, and pool exhaustion
*preempts* the latest-admitted slot — its blocks return to the free list
and its request requeues at the front with generated tokens preserved.
``ops.use(attention="paged")`` (or an ``attn_impl="paged"`` config) flips
the layout without touching engine construction.

Greedy continuous-batching output is bit-identical to sequential
``ServeEngine.generate`` calls for the same prompts (tests/test_serve.py);
with temperature, each request gets its own PRNG stream (folded from its
uid), so sampled output is also independent of pool co-tenancy.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.obs import MetricsRegistry, NullTracer, Tracer, get_tracer
from repro.configs.base import ModelConfig
from repro.models.registry import build_model
from repro.models.transformer import DecoderLM
from repro.ops.registry import active_overrides
from repro.serve.paged import SCRATCH_BLOCK, BlockPool, PrefixCache, bucket_blocks
from repro.serve.scheduler import Request, Slot, SlotScheduler

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0  # 0 = greedy
    star_sampling: bool = True  # STAR softmax on the output distribution


def sample_token(
    logits: jax.Array,  # [..., V]
    key: jax.Array,
    cfg: ModelConfig,
    serve_cfg: ServeConfig,
    guard: Optional["ops.AccuracyGuard"] = None,
) -> jax.Array:
    """Greedy or temperature sampling, through the STAR engine when
    configured (the quantized LUT softmax shapes the sampling distribution
    exactly like it shapes attention rows).

    ``guard`` routes the sampling softmax through the accuracy guard
    (eager call sites only — it compares against the exact oracle on the
    host, see ``repro.ops.guard``)."""
    t = serve_cfg.temperature
    if t <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / t
    spec = cfg.softmax_spec
    if serve_cfg.star_sampling and spec.kind != "exact":
        probs = ops.softmax(scaled, spec, guard=guard)
        return jax.random.categorical(
            key, jnp.log(jnp.maximum(probs, 1e-20)), axis=-1
        ).astype(jnp.int32)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


class ServeEngine:
    """Lockstep batch engine: one prefill, then synchronized decode."""

    def __init__(self, model_cfg: ModelConfig, params: PyTree, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = model_cfg
        self.params = params
        self.serve_cfg = serve_cfg
        self.model = build_model(model_cfg)
        self._decode = jax.jit(self.model.decode_step)

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        return sample_token(logits, key, self.cfg, self.serve_cfg)

    def generate(
        self,
        prompts: jax.Array,  # [B, T] token prompts
        num_tokens: int,
        *,
        key: Optional[jax.Array] = None,
        **frontend,  # patch_embeds / src_embeds stubs
    ) -> Tuple[jax.Array, Dict[str, Any]]:
        key = key if key is not None else jax.random.PRNGKey(0)
        b, t = prompts.shape
        max_len = self.serve_cfg.max_len
        logits, cache = self.model.prefill(self.params, prompts, max_len, **frontend)
        outs = []
        tok = self._sample(logits[:, -1], key)[:, None]
        outs.append(tok)
        for i in range(num_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits[:, -1], sub)[:, None]
            outs.append(tok)
        generated = jnp.concatenate(outs, axis=1)
        return generated, {"cache_len": int(jax.device_get(cache["len"]))}


# ---------------------------------------------------------------------------
# Continuous batching


@dataclasses.dataclass
class ContinuousConfig:
    num_slots: int = 8  # KV-cache pool size (max concurrent requests)
    max_len: int = 512  # per-slot cache capacity (prompt + generation)
    temperature: float = 0.0
    star_sampling: bool = True
    # Paged KV cache (DESIGN.md §8).  "dense" keeps the PR-1 per-slot
    # buffers; "paged" stores K/V in fixed-size token blocks behind
    # per-request block tables (serve/paged.py) so memory tracks live
    # tokens.  ``ops.use(attention="paged")`` — or a config whose
    # attention impl is "paged" — flips the layout too.
    kv_layout: str = "dense"  # dense | paged
    kv_block_size: int = 16  # tokens per KV block
    # usable blocks in the pool (scratch excluded); None sizes it to the
    # dense-equivalent capacity num_slots * ceil(cache_len / block_size)
    kv_pool_blocks: Optional[int] = None
    # Shared-prefix KV cache (DESIGN.md §12): a radix trie over token-id
    # block chunks maps a new request's longest cached prefix to existing
    # pool blocks (refcount++), so admission skips prefill for the shared
    # prefix.  Paged layout only; rings and MoE archs silently opt out
    # (their KV/expert state is not prefix-local — see PrefixCache docs).
    prefix_cache: bool = False
    # Chunked prefill: budget of prompt tokens processed per engine tick.
    # Admitted prompts stream through in power-of-two chunks interleaved
    # with decode ticks instead of head-of-line-blocking the pool; None
    # keeps the monolithic admission prefill.
    prefill_chunk_tokens: Optional[int] = None
    # Quantized KV page storage (DESIGN.md §13): "int8" / "fp8_e4m3" store
    # codes + per-(block, head) scale pages in the page pool and the decode
    # kernel dequantizes in-kernel.  Paged layout only — the dense per-slot
    # pool has no block granularity to hang scales off.
    kv_dtype: str = "fp32"  # fp32 | int8 | fp8_e4m3
    # Accuracy guard on the sampling softmax (DESIGN.md §9): sampled
    # comparison against the exact oracle, fallback to a clean backend
    # when a degraded (faulty / over-quantized) spec exceeds tolerance.
    # Counters surface through ``ContinuousBatchingEngine.stats()``.
    guard: Optional["ops.GuardConfig"] = None

    def as_serve_config(self) -> ServeConfig:
        return ServeConfig(self.max_len, self.temperature, self.star_sampling)


@dataclasses.dataclass
class TokenEvent:
    """One emitted token: streamed to ``on_token`` and returned by step()."""

    uid: int
    token: int
    index: int  # 0-based position within the request's generation
    finished: bool


class ContinuousBatchingEngine:
    """Slot-pool serving: admit, decode the whole pool per tick, retire.

    Host-side control (the :class:`SlotScheduler`) decides *which* requests
    occupy which slots; the device-side tick is a single jitted
    ``decode_step`` over the ``[num_slots, 1]`` token matrix.  Free slots
    decode garbage that is masked (their ``len`` counter is 0) and simply
    discarded — the fixed shape is what keeps the step jit-stable.
    """

    def __init__(
        self,
        model_cfg: ModelConfig,
        params: PyTree,
        cb_cfg: ContinuousConfig = ContinuousConfig(),
        *,
        base_key: Optional[jax.Array] = None,
        on_token: Optional[Callable[[TokenEvent], None]] = None,
        tracer: Optional[Tracer | NullTracer] = None,
        metrics: Optional[MetricsRegistry] = None,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.cfg = model_cfg
        self.params = params
        self.cb = cb_cfg
        # Observability (DESIGN.md §10).  The tracer binds at construction:
        # the global no-op singleton unless obs.enable_tracing() ran first
        # (or one is injected).  Metrics live in a per-engine registry so
        # stats() snapshots are isolated; ``clock`` is injectable for
        # deterministic latency tests (tests/test_obs_serve.py).
        self.tracer = tracer if tracer is not None else get_tracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._clock = clock if clock is not None else time.perf_counter
        reg = self.metrics
        self._m_submitted = reg.counter("serve.requests.submitted")
        self._m_admitted = reg.counter(
            "serve.requests.admitted", "admissions incl. re-admissions")
        self._m_finished = reg.counter("serve.requests.finished")
        self._m_preempted = reg.counter("serve.requests.preempted")
        self._m_tokens = reg.counter("serve.tokens.generated")
        self._h_ttft = reg.histogram(
            "serve.ttft_s", "submit -> first token (end-to-end, survives "
            "preemption)")
        self._h_itl = reg.histogram(
            "serve.itl_s", "inter-token latency per request")
        self._h_queue = reg.histogram(
            "serve.queue_wait_s", "pending-queue wait per admission stint")
        self._g_queue = reg.gauge("serve.queue.depth")
        self._g_active = reg.gauge("serve.slots.active")
        # Transfer / retrace accounting (DESIGN.md §11): counted bytes the
        # tick moves across the host-device boundary, the counted KV bytes
        # decode reads out of the page pool (traffic model, not a
        # measurement), and the pooled jit-cache entry count.
        self._m_h2d = reg.counter(
            "serve.bytes.h2d", "host->device bytes per tick (token inputs, "
            "dirty table rows, sampling uid/step vectors)")
        self._m_d2h = reg.counter(
            "serve.bytes.d2h", "device->host bytes per tick (the sampled "
            "token vector; admission adds one token per prefill)")
        self._m_gather = reg.counter(
            "kv.gather.bytes", "counted K+V bytes decode reads from the KV "
            "pool (ops.paged_gather_bytes traffic model)")
        self._g_jit = reg.gauge(
            "serve.jit.entries", "pooled jit-cache entries across the "
            "engine's compiled callables")
        self.model = build_model(model_cfg)
        if not isinstance(self.model, DecoderLM):
            raise ValueError(
                "continuous batching needs the per-slot KV-cache pool, which "
                f"only attention-family models implement (got {model_cfg.family!r})"
            )
        self.scheduler = SlotScheduler(cb_cfg.num_slots)
        # KV layout: the config picks it, and the "paged" marker impl —
        # via ops.use(attention="paged") or the config's own attention
        # spec — flips the whole serve stack to the block-pool cache.
        layout = cb_cfg.kv_layout
        if (
            active_overrides("attention").get("impl") == "paged"
            or model_cfg.attention_spec.impl == "paged"
        ):
            layout = "paged"
        if layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout must be 'dense' or 'paged', got {layout!r}")
        self.kv_layout = layout
        self._cache_t = self.model.cache_len(cb_cfg.max_len)
        # ring caches (sliding window shorter than max_len) wrap in place:
        # their blocks are allocated once per admission, never appended
        self._ring = (
            model_cfg.sliding_window is not None
            and self._cache_t <= model_cfg.sliding_window
        )
        if layout == "paged":
            bs = cb_cfg.kv_block_size
            self._slot_blocks = -(-self._cache_t // bs)  # table width W
            usable = cb_cfg.kv_pool_blocks
            if usable is None:
                usable = cb_cfg.num_slots * self._slot_blocks
            self.block_pool = BlockPool(
                usable + 1, bs,  # +1: scratch block 0
                kv_dtype=cb_cfg.kv_dtype, metrics=self.metrics,
            )
            if self._ring and self._slot_blocks > self.block_pool.usable_blocks:
                raise ValueError(
                    f"a sliding-window ring needs {self._slot_blocks} blocks "
                    f"per slot but the pool only has "
                    f"{self.block_pool.usable_blocks}; raise kv_pool_blocks"
                )
            self.pool = self.model.init_paged_cache(
                usable + 1, bs, cb_cfg.num_slots, kv_dtype=cb_cfg.kv_dtype
            )
            self._tables = np.full(
                (cb_cfg.num_slots, self._slot_blocks), SCRATCH_BLOCK, np.int32
            )
            self._rows = np.zeros(cb_cfg.num_slots, np.int64)  # KV rows written
            # Device-resident mirror of the block tables (DESIGN.md §11):
            # the tick reads this array directly instead of uploading the
            # whole [S, W] host table every step.  Host-side allocator
            # edits mark their slot dirty; the flush before decode pushes
            # only the dirty rows through a donated row update, so steady
            # decode (no allocation churn) uploads zero table bytes.
            self._tables_dev = jnp.full(
                (cb_cfg.num_slots, self._slot_blocks), SCRATCH_BLOCK, jnp.int32
            )
            self._dirty_tables: set = set()
            self._push_row = jax.jit(
                lambda tab, i, row: tab.at[i].set(row), donate_argnums=(0,)
            )
            # slot index stays a *traced* argument (``.at[slot].set`` takes
            # a dynamic index) so the admission write compiles per bucketed
            # table width only — not per (slot, width) pair
            self._write_slot_paged = jax.jit(
                self.model.write_slot_paged, donate_argnums=(0,)
            )
            self.preemptions = 0  # OOM evictions (requeued, not dropped)
            self.peak_used_blocks = 0
        else:
            if cb_cfg.kv_dtype != "fp32":
                raise ValueError(
                    f"kv_dtype={cb_cfg.kv_dtype!r} requires kv_layout='paged' "
                    "(scales are per-block; the dense per-slot pool has no "
                    "blocks) — pass kv_layout='paged' or drop kv_dtype"
                )
            self.block_pool = None
            self.pool = self.model.init_pool_cache(cb_cfg.num_slots, cb_cfg.max_len)
            # donate the pool everywhere it is threaded through: the tick,
            # the admission write, and the retirement reset all update it in
            # place instead of copying the whole [L, S, T, H, D] pool
            # (self.pool is rebound to the result each call, so the old
            # buffer is never live)
            self._write_slot = jax.jit(
                self.model.write_slot, donate_argnums=(0,))
        self._reset_slot = jax.jit(
            self.model.reset_slot, donate_argnums=(0,))
        # Shared-prefix cache + chunked prefill (DESIGN.md §12).  Either
        # flag routes admission through the staging path; with both off the
        # monolithic admission prefill below is untouched.
        if cb_cfg.prefill_chunk_tokens is not None and cb_cfg.prefill_chunk_tokens < 1:
            raise ValueError(
                f"prefill_chunk_tokens must be >= 1, got {cb_cfg.prefill_chunk_tokens}"
            )
        if cb_cfg.prefix_cache and layout != "paged":
            raise ValueError(
                "prefix_cache requires kv_layout='paged' (the dense pool has "
                "no shareable blocks); pass kv_layout='paged' or drop the flag"
            )
        self._chunked = cb_cfg.prefill_chunk_tokens is not None or cb_cfg.prefix_cache
        self.prefix: Optional[PrefixCache] = None
        if (
            cb_cfg.prefix_cache
            and not self._ring
            and model_cfg.family != "moe"
        ):
            # rings opt out (a wrapped window no longer holds the prefix
            # rows a later request would adopt) and so do MoE archs (expert
            # queue positions are sequence-global, so cached prefix KV is
            # not sufficient state to resume from) — both still get chunked
            # prefill, just no cross-request sharing
            self.prefix = PrefixCache(self.block_pool, metrics=self.metrics)
        self._staging: Dict[int, Dict[str, Any]] = {}
        self._serve_cfg = cb_cfg.as_serve_config()
        # one stateful guard for the engine's lifetime: counters accumulate
        # across ticks and the trip latch persists (degraded part stays on
        # the clean path once caught)
        self.guard = (
            ops.AccuracyGuard(cb_cfg.guard) if cb_cfg.guard is not None else None
        )
        self._base_key = base_key if base_key is not None else jax.random.PRNGKey(0)
        self._on_token = on_token
        self._inputs = np.zeros((cb_cfg.num_slots, 1), np.int32)  # next token per slot
        self._frontend: Dict[int, Dict[str, jax.Array]] = {}
        self.ticks = 0  # decode ticks executed (for utilization accounting)
        self._tick = self._build_tick()

    def _build_tick(self):
        """The fused device tick: decode the whole pool AND sample every
        slot inside one jitted program, so a steady tick performs a single
        D2H transfer — the ``[S]`` sampled-token vector (DESIGN.md §11).

        Free slots sample garbage from garbage keys; the host discards
        them (the scheduler owns occupancy).  The guarded sampling path
        cannot fold in — the accuracy guard compares against the exact
        oracle on the host — so the tick also returns the last-token
        logits as a *device* array: the guard path fetches it, everyone
        else never does.
        """
        cfg, serve_cfg = self.cfg, self._serve_cfg
        model, cache_t = self.model, self._cache_t
        base_key, paged = self._base_key, self.kv_layout == "paged"

        def tick(params, pool, inputs, tables, uids, steps):
            if paged:
                logits, pool = model.decode_step_paged(
                    params, pool, inputs, tables, cache_t=cache_t
                )
            else:
                logits, pool = model.decode_step(params, pool, inputs)
            last = logits[:, -1]  # [S, V]
            if serve_cfg.temperature <= 0.0:
                sampled = jnp.argmax(last, axis=-1).astype(jnp.int32)
            else:
                keys = jax.vmap(
                    lambda u, i: jax.random.fold_in(
                        jax.random.fold_in(base_key, u), i
                    )
                )(uids, steps)
                sampled = jax.vmap(
                    lambda lg, k: sample_token(lg, k, cfg, serve_cfg)
                )(last, keys)
            return sampled, last, pool

        return jax.jit(tick, donate_argnums=(1,))

    def jit_cache_entries(self) -> int:
        """Pooled compiled-variant count across the engine's jitted
        callables — the retrace observable (tests/test_serve_retrace.py):
        a repeated workload must not grow it, and mixed-length paged
        traffic must grow the admission write O(log W), not O(n)."""
        fns = [self._tick, self._reset_slot]
        fns.append(
            self._write_slot_paged if self.kv_layout == "paged"
            else self._write_slot
        )
        if self.kv_layout == "paged":
            fns.append(self._push_row)
        return int(sum(f._cache_size() for f in fns))

    # -- submission ---------------------------------------------------------

    def submit(
        self,
        prompt: Sequence[int] | np.ndarray,
        max_new_tokens: int,
        *,
        eos_id: Optional[int] = None,
        arrival_time: float = 0.0,
        **frontend,
    ) -> int:
        """Queue a request (never blocks); returns its uid."""
        prefix = self._prefix_rows(frontend)
        need = prefix + len(prompt) + max_new_tokens - 1
        if self.cfg.sliding_window is None:
            # decode writes prompt + (max_new_tokens - 1) K/V rows (the last
            # sampled token is never fed back); past capacity the per-slot
            # write would silently drop rows, so reject up front
            if need > self.cb.max_len:
                raise ValueError(
                    f"request needs {need} cache rows (prompt {len(prompt)} "
                    f"+ prefix {prefix} + {max_new_tokens} new tokens) but "
                    f"the pool was built with max_len={self.cb.max_len}"
                )
        if self.kv_layout == "paged":
            # a request larger than the whole pool could never be admitted,
            # even with every other slot preempted — reject it up front
            blocks = (
                self._slot_blocks if self._ring
                else self.block_pool.blocks_for_tokens(need)
            )
            if blocks > self.block_pool.usable_blocks:
                raise ValueError(
                    f"request needs {blocks} KV blocks "
                    f"({need} rows at block_size="
                    f"{self.block_pool.block_size}) but the pool only has "
                    f"{self.block_pool.usable_blocks}; raise kv_pool_blocks "
                    f"or kv_block_size, or split the request"
                )
        uid = self.scheduler.submit(
            prompt, max_new_tokens, eos_id=eos_id, arrival_time=arrival_time
        )
        if frontend:
            self._frontend[uid] = {k: jnp.asarray(v) for k, v in frontend.items()}
        now = self._clock()
        req = self.scheduler.pending[-1]
        req.submit_time = req.enqueued_at = now
        self._m_submitted.inc()
        self._g_queue.set(len(self.scheduler.pending))
        tracer = self.tracer
        if tracer.enabled:
            tracer.instant("serve.submit", uid=uid, prompt_len=len(prompt),
                           max_new_tokens=max_new_tokens)
            # one async track per request, open from submit to finish —
            # Perfetto renders queue wait + every decode stint on one row
            tracer.async_begin("request", uid)
        return uid

    # -- the tick -----------------------------------------------------------

    def _prefix_rows(self, frontend: Dict[str, Any]) -> int:
        """KV rows the frontend prepends before the prompt (VLM patches).
        Used by both the submit-time capacity check and the admission
        block allocation — one definition so they can never diverge."""
        if self.cfg.family == "vlm" and "patch_embeds" in frontend:
            return self.cfg.num_patches
        return 0

    def _request_key(self, req: Request, index: int) -> jax.Array:
        # Per-request stream, independent of slot placement and co-tenants.
        return jax.random.fold_in(jax.random.fold_in(self._base_key, req.uid), index)

    def _emit(self, slot: Slot, token: int, finished: bool) -> TokenEvent:
        req = slot.request
        index = len(req.generated_prefix) + len(slot.generated) - 1
        ev = TokenEvent(req.uid, token, index, finished)
        now = self._clock()
        if req.first_token_time is None:
            if req.submit_time is not None:
                self._h_ttft.observe(now - req.submit_time)
            req.first_token_time = now
        elif req.last_token_time is not None:
            self._h_itl.observe(now - req.last_token_time)
        req.last_token_time = now
        self._m_tokens.inc()
        if self._on_token is not None:
            self._on_token(ev)
        return ev

    def _finish(self, slot: Slot) -> None:
        req = self.scheduler.retire(slot)
        self._frontend.pop(req.uid, None)
        if self.kv_layout == "paged":
            self.block_pool.release(req.uid)
            self._tables[slot.index, :] = SCRATCH_BLOCK
            self._dirty_tables.add(slot.index)
        self.pool = self._reset_slot(self.pool, slot.index)
        self._m_finished.inc()
        if self.tracer.enabled:
            self.tracer.instant("serve.finish", uid=req.uid,
                                tokens=len(self.scheduler.finished[req.uid]))
            self.tracer.async_end("request", req.uid)

    # -- paged-pool block management -----------------------------------------

    def _preempt(self, slot: Slot) -> None:
        """Evict ``slot``'s request (OOM policy): release its blocks back
        to the pool and requeue it at the front of the pending queue.  Its
        generated tokens fold into the request, so on re-admission it
        re-prefills ``prompt + generated_prefix`` and resumes mid-stream
        — greedy output and per-request PRNG streams are unaffected."""
        self._staging.pop(slot.index, None)  # drop any in-flight chunk state
        req = self.scheduler.preempt(slot)  # keeps FIFO priority
        # a victim bound this very tick but not yet prefilled owns no
        # blocks yet — nothing to release (staging slots may own adopted
        # prefix blocks, which this returns/unshares)
        if req.uid in self.block_pool.owners():
            self.block_pool.release(req.uid)
        self._tables[slot.index, :] = SCRATCH_BLOCK
        self._dirty_tables.add(slot.index)
        self.pool = self._reset_slot(self.pool, slot.index)
        self.preemptions += 1
        # queue-wait restarts for this stint — but only if the previous
        # stint was already observed at admission (enqueued_at consumed).
        # A victim preempted before its admission observe ran (bound this
        # very tick, then evicted by an earlier admission) still carries
        # its original stamp: restamping would silently drop that whole
        # wait stint from serve.queue_wait_s.
        if req.enqueued_at is None:
            req.enqueued_at = self._clock()
        self._m_preempted.inc()
        self.tracer.instant(
            "serve.preempt", uid=req.uid,
            generated=len(req.generated_prefix),
        )

    def _lowest_priority_victim(self, min_uid: int) -> Optional[Slot]:
        """The occupied slot with the largest uid above ``min_uid`` —
        latest-admitted work is evicted first (FIFO priority: earlier
        requests never yield to later ones).  Prefilling slots are fair
        game: staged chunk work is cheaper to redo than decoded tokens."""
        victims = [
            s for s in self.scheduler.occupied_slots if s.request.uid > min_uid
        ]
        return max(victims, key=lambda s: s.request.uid) if victims else None

    def _reclaim_blocks(self, n: int, min_uid: int) -> bool:
        """Make ``n`` blocks allocatable: evict cold prefix-trie leaves
        first (cached KV is cheaper to lose than live work), then preempt
        later-admitted slots.  False when neither can free enough."""
        while not self.block_pool.can_allocate(n):
            if self.prefix is not None and self.prefix.evict_one():
                continue
            victim = self._lowest_priority_victim(min_uid)
            if victim is None:
                return False
            self._preempt(victim)
        return True

    def _note_peak(self) -> None:
        """Record the allocator high-water mark at allocation time, so
        transients that release within the same tick still count."""
        self.peak_used_blocks = max(
            self.peak_used_blocks, self.block_pool.used_blocks
        )

    def _admit_blocks(self, slot: Slot, rows: int) -> bool:
        """Allocate the admission block table for ``rows`` prefill rows,
        preempting lower-priority slots on exhaustion.  Returns False (and
        requeues the request) if the pool cannot fit it even then."""
        req = slot.request
        n = (
            self._slot_blocks if self._ring
            else self.block_pool.blocks_for_tokens(rows)
        )
        if not self._reclaim_blocks(n, req.uid):
            self.scheduler.pending.appendleft(slot.release())
            return False
        blocks = self.block_pool.allocate(req.uid, n)
        self._tables[slot.index, :] = SCRATCH_BLOCK
        self._tables[slot.index, :n] = blocks
        self._dirty_tables.add(slot.index)
        self._note_peak()
        return True

    def _ensure_decode_block(self, slot: Slot) -> bool:
        """Grow the slot's table when this tick's KV write opens a new
        block (non-ring only; rings wrap in place).  Preempts on
        exhaustion — possibly the slot itself when it *is* the
        lowest-priority occupant.  Returns False if the slot was evicted."""
        if self._ring:
            return True
        rows = int(self._rows[slot.index])
        if rows % self.block_pool.block_size != 0:
            return True  # current block still has room
        req = slot.request
        while not self.block_pool.can_allocate(1):
            if self.prefix is not None and self.prefix.evict_one():
                continue
            victim = self._lowest_priority_victim(-1)
            if victim is None or victim is slot:
                self._preempt(slot)
                return False
            self._preempt(victim)
        blk = self.block_pool.append(req.uid)
        self._tables[slot.index, rows // self.block_pool.block_size] = blk
        self._dirty_tables.add(slot.index)
        self._note_peak()
        return True

    # -- chunked prefill + prefix cache (DESIGN.md §12) ------------------------

    def _staging_rows(self, rows: int) -> int:
        """Linear staging-cache capacity for a ``rows``-row prompt.

        Rings stage past the window (power of two >= max(rows, window+1))
        so chunks append linearly before ``finalize_ring_cache`` folds the
        buffer; non-ring paged staging matches the bucketed admission block
        grid exactly (same jit variants as the monolithic write); dense
        non-ring staging is the pool row itself."""
        if self._ring:
            need = max(rows, self.cfg.sliding_window + 1)
            ts = 1
            while ts < need:
                ts *= 2
            return ts
        if self.kv_layout == "paged":
            nb = bucket_blocks(
                self.block_pool.blocks_for_tokens(rows), self._slot_blocks
            )
            return nb * self.block_pool.block_size
        return self._cache_t

    def _admit_staging(self, slot: Slot) -> None:
        """Bind an admitted request to the chunked-prefill path: adopt any
        trie-cached prefix blocks (skipping their prefill outright), size
        the linear staging cache, and queue the uncached suffix for
        budgeted chunk processing (``_run_prefill_chunks``)."""
        req = slot.request
        fe = self._frontend.get(req.uid, {})
        tokens = np.concatenate(
            [req.prompt, np.asarray(req.generated_prefix, np.int32)]
        ) if req.generated_prefix else np.asarray(req.prompt, np.int32)
        rows = self._prefix_rows(fe) + len(tokens)
        p0, shared = 0, []
        if self.prefix is not None and not fe:
            # frontend prefixes (VLM patches) shift rows past the token
            # grid, so such requests never share — token-only lookups
            shared, p0 = self.prefix.lookup(tokens)
            if shared:
                self.block_pool.adopt(req.uid, shared)
        self._staging[slot.index] = {
            "req": req,
            "fe": fe,
            "tokens": tokens,
            "rows": rows,
            "p0": p0,
            "shared": list(shared),
            "suffix": tokens[p0:],
            "done": 0,
            "cache": None,
            "logits": None,
            "Ts": self._staging_rows(rows),
            "moe_cap": self.model.moe_prefill_capacity(rows),
        }
        slot.prefilling = True
        now = self._clock()
        if req.enqueued_at is not None:
            self._h_queue.observe(now - req.enqueued_at)
            req.enqueued_at = None  # consumed: a later preempt restamps
        self._m_admitted.inc()
        if self.tracer.enabled:
            self.tracer.instant(
                "serve.admit", uid=req.uid, slot=slot.index, rows=rows,
                prefix_rows=p0,
            )

    def _run_prefill_chunks(self) -> List[TokenEvent]:
        """Feed the tick's prompt-token budget through staging slots (FIFO
        by uid, power-of-two chunks); write completed prefills into the
        pool and sample their first token."""
        events: List[TokenEvent] = []
        budget = self.cb.prefill_chunk_tokens or (1 << 30)
        for idx in sorted(self._staging, key=lambda i: self._staging[i]["req"].uid):
            if budget <= 0:
                break
            st = self._staging.get(idx)
            if st is None:
                continue  # preempted by an earlier completion this tick
            req, suffix = st["req"], st["suffix"]
            while budget > 0 and st["done"] < len(suffix):
                c = min(len(suffix) - st["done"], budget)
                c = 1 << (int(c).bit_length() - 1)  # pow2: bounded variants
                chunk = suffix[st["done"]:st["done"] + c]
                with self.tracer.span(
                    "serve.prefill_chunk", uid=req.uid, tokens=int(c),
                    done=st["done"] + int(c), total=len(suffix),
                ):
                    if st["cache"] is None and st["p0"]:
                        # seed the staging buffer with the cached prefix
                        # rows straight out of the page pool — this is the
                        # prefill work the trie hit saves
                        st["cache"] = self.model.gather_prefix_cache(
                            self.pool, st["shared"], st["p0"], st["Ts"]
                        )
                    if st["cache"] is None:
                        st["logits"], st["cache"] = self.model.prefill(
                            self.params, jnp.asarray(chunk)[None],
                            self.cb.max_len, cache_t=st["Ts"],
                            moe_capacity=st["moe_cap"], **st["fe"]
                        )
                    else:
                        st["logits"], st["cache"] = self.model.prefill_extend(
                            self.params, st["cache"], jnp.asarray(chunk)[None],
                            moe_capacity=st["moe_cap"],
                        )
                self._m_h2d.inc(int(c) * 4)
                st["done"] += int(c)
                budget -= int(c)
            if st["done"] == len(suffix):
                ev = self._finish_prefill(idx)
                if ev is not None:
                    events.append(ev)
        return events

    def _strip_staging_cache(self, cache: PyTree) -> PyTree:
        """Drop chunk-only staging state (MoE queue counts) before the
        pool write — decode is stateless, exactly like the monolithic
        path."""
        return {
            "layers": {
                "k": cache["layers"]["k"], "v": cache["layers"]["v"],
            },
            "len": cache["len"],
            "pos": cache["pos"],
        }

    def _finish_prefill(self, idx: int) -> Optional[TokenEvent]:
        """Write a completed staging prefill into the pool, index its full
        blocks in the prefix trie, and sample the request's first token.
        Returns None when the pool could not fit the fresh blocks even
        after eviction/preemption (the request requeues, like the
        monolithic ``_admit_blocks`` failure path)."""
        st = self._staging.pop(idx)
        slot = self.scheduler.slots[idx]
        req, rows = st["req"], st["rows"]
        cache = st["cache"]
        if self.kv_layout == "paged":
            bp = self.block_pool
            if self._ring:
                n_real = n_fresh = self._slot_blocks  # rings never adopt
            else:
                n_real = bp.blocks_for_tokens(rows)
                n_fresh = n_real - len(st["shared"])
            if not self._reclaim_blocks(n_fresh, req.uid):
                self._requeue_staging(slot, st)
                return None
            if req.uid in bp.owners():  # adopted a prefix at admission
                fresh = [bp.append(req.uid) for _ in range(n_fresh)]
            else:
                fresh = bp.allocate(req.uid, n_fresh)
            table_row = st["shared"] + fresh
            self._tables[idx, :] = SCRATCH_BLOCK
            self._tables[idx, :n_real] = table_row
            self._dirty_tables.add(idx)
            self._note_peak()
            if self._ring:
                cache = self.model.finalize_ring_cache(cache, self._cache_t)
                write_table = table_row
            else:
                # the adopted prefix rows already live in the pool: scatter
                # them to scratch so the write cannot disturb shared blocks
                # (CoW discipline), and pad to the bucketed grid
                width = st["Ts"] // bp.block_size
                write_table = (
                    [SCRATCH_BLOCK] * len(st["shared"]) + fresh
                    + [SCRATCH_BLOCK] * (width - n_real)
                )
            if "moe" in cache["layers"]:
                cache = self._strip_staging_cache(cache)
            self.pool = self._write_slot_paged(
                self.pool, cache, idx, jnp.asarray(write_table, jnp.int32)
            )
            self._m_h2d.inc(len(write_table) * 4)
            self._rows[idx] = rows
            if self.prefix is not None and not st["fe"]:
                self.prefix.insert(st["tokens"], table_row)
        else:
            if self._ring:
                cache = self.model.finalize_ring_cache(cache, self._cache_t)
            elif "moe" in cache["layers"]:
                cache = self._strip_staging_cache(cache)
            self.pool = self._write_slot(self.pool, cache, idx)
        slot.prefilling = False
        self._m_d2h.inc(4)  # the admission-sampled token below
        tok = int(sample_token(
            st["logits"][0, -1],
            self._request_key(req, len(req.generated_prefix)),
            self.cfg, self._serve_cfg, guard=self.guard,
        ))
        finished = self.scheduler.record_token(slot, tok)
        ev = self._emit(slot, tok, finished)
        self._inputs[idx, 0] = tok
        if finished:
            self._finish(slot)
        return ev

    def _requeue_staging(self, slot: Slot, st: Dict[str, Any]) -> None:
        """Completion found no room even after eviction/preemption: drop
        the staged work and wait in line (the chunked counterpart of the
        monolithic ``_admit_blocks`` False path)."""
        req = st["req"]
        if req.uid in self.block_pool.owners():
            self.block_pool.release(req.uid)  # return adopted prefix blocks
        req.enqueued_at = self._clock()  # admission observed; new stint
        self.scheduler.pending.appendleft(slot.release())
        self._tables[slot.index, :] = SCRATCH_BLOCK
        self._dirty_tables.add(slot.index)
        self.pool = self._reset_slot(self.pool, slot.index)

    def kv_row_bytes(self) -> int:
        """Bytes one KV token row costs across all layers (K + V).

        Derived from the *actual* cache leaf dtypes — a quantized pool's
        int8/fp8 codes count one byte per element, not the compute dtype's
        four — so every byte figure downstream (kv_stats, benchmarks, CI's
        compression-ratio gate) reflects what the pool really stores.
        """
        layers = self.pool["layers"]
        num_layers = layers["k"].shape[0]
        per_head = int(np.prod(layers["k"].shape[-2:]))
        return num_layers * per_head * (
            layers["k"].dtype.itemsize + layers["v"].dtype.itemsize
        )

    def kv_scale_bytes_per_block(self) -> int:
        """Scale-page overhead per block across all layers (0 at fp32)."""
        layers = self.pool["layers"]
        if "k_scale" not in layers:
            return 0
        ks, vs = layers["k_scale"], layers["v_scale"]
        num_layers, _, hkv = ks.shape
        return num_layers * hkv * (ks.dtype.itemsize + vs.dtype.itemsize)

    def kv_stats(self) -> Dict[str, Any]:
        """Live KV-memory accounting (benchmarks/serve_throughput.py).

        ``kv_bytes_in_use`` is what an allocator has to *pin* right now:
        the dense layout pins its full ``num_slots * cache_len`` buffer
        regardless of occupancy; the paged layout pins only allocated
        blocks."""
        row_bytes = self.kv_row_bytes()
        if self.kv_layout == "paged":
            bs = self.block_pool.block_size
            prefix_stats = None
            if self.cb.prefix_cache:
                p = self.prefix
                prefix_stats = {
                    "hits": p.hits if p else 0,
                    "tokens_saved": p.tokens_saved if p else 0,
                    "evicted": p.evicted if p else 0,
                    "nodes": len(p) if p else 0,
                }
            # a block's full footprint: its token rows plus (quantized
            # layouts only) its per-(layer, head) scale rows
            block_bytes = bs * row_bytes + self.kv_scale_bytes_per_block()
            return {
                "prefix": prefix_stats,
                "layout": "paged",
                "kv_dtype": self.block_pool.kv_dtype,
                "used_blocks": self.block_pool.used_blocks,
                "free_blocks": self.block_pool.free_blocks,
                "total_blocks": self.block_pool.usable_blocks,
                # amortized storage cost of one cached token, scale pages
                # included — the benchmark/CI compression-ratio numerator
                "kv_bytes_per_token": block_bytes / bs,
                "kv_bytes_in_use": self.block_pool.used_blocks * block_bytes,
                "kv_bytes_capacity": (
                    self.block_pool.usable_blocks * block_bytes
                ),
                "peak_kv_bytes": self.peak_used_blocks * block_bytes,
                "preemptions": self.preemptions,
                "peak_used_blocks": self.peak_used_blocks,
                # counted decode traffic (ops.paged_gather_bytes): what
                # the resolved paged backend reads from the page pool —
                # gather adapters pay the full table window, pallas_paged
                # pays live pages only (DESIGN.md §11)
                "gather_bytes": self._m_gather.value(),
                "gather_bytes_per_token": (
                    self._m_gather.value() / max(self._m_tokens.value(), 1.0)
                ),
            }
        rows = self.cb.num_slots * self._cache_t
        return {
            "layout": "dense",
            "kv_dtype": "fp32",
            "kv_bytes_per_token": float(row_bytes),
            "kv_bytes_in_use": rows * row_bytes,
            "kv_bytes_capacity": rows * row_bytes,
            "peak_kv_bytes": rows * row_bytes,
        }

    def stats(self) -> Dict[str, Any]:
        """Engine-level counters: ticks, KV accounting, the engine's
        metrics-registry snapshot (request lifecycle histograms, queue /
        occupancy gauges, block-pool counters — DESIGN.md §10), and —
        when an accuracy guard is configured — its trip/fallback counters
        (calls / checks / trips / fallbacks / tripped / last_error)."""
        out: Dict[str, Any] = {"ticks": self.ticks, "kv": self.kv_stats()}
        out["guard"] = self.guard.stats() if self.guard is not None else None
        out["metrics"] = self.metrics.snapshot()
        return out

    # -- the tick (continued) ------------------------------------------------

    def step(self) -> List[TokenEvent]:
        """One engine tick: admit + prefill new requests (allocating KV
        blocks under the paged layout, preempting on exhaustion), then one
        jitted decode across the pool.  Returns the tokens emitted."""
        events: List[TokenEvent] = []
        paged = self.kv_layout == "paged"

        # 1. admission: prefill pending requests into free slots.  Decode
        #    state of already-active slots is untouched — they proceed on
        #    the same tick below.  A preempted request re-prefills its
        #    prompt plus everything it had generated.
        for slot in self.scheduler.admit():
            if slot.free:
                continue  # preempted by an earlier admission this tick
            if self._chunked:
                # staging path: prefix-cache lookup + budgeted chunk
                # prefill over the next ticks (DESIGN.md §12)
                self._admit_staging(slot)
                continue
            req = slot.request
            fe = self._frontend.get(req.uid, {})
            tokens = np.concatenate(
                [req.prompt, np.asarray(req.generated_prefix, np.int32)]
            ) if req.generated_prefix else req.prompt
            rows = self._prefix_rows(fe) + len(tokens)
            if paged:
                if not self._admit_blocks(slot, rows):
                    continue  # pool full even after preemption: wait in line
                # prefill only as many rows as the table holds: the block
                # grid, not max_len, sizes the single-request cache (rings
                # keep the full window — they wrap in place).  The width is
                # *bucketed* to the next power of two (serve.paged
                # .bucket_blocks): extra table entries point at scratch and
                # extra prefill rows are masked garbage, so the jitted
                # write_slot_paged compiles O(log W) variants under
                # mixed-length traffic instead of one per block count
                # (DESIGN.md §11; the slot index itself is traced)
                n_blocks = (
                    self._slot_blocks if self._ring
                    else bucket_blocks(
                        self.block_pool.blocks_for_tokens(rows),
                        self._slot_blocks,
                    )
                )
                prefill_len = (
                    self.cb.max_len if self._ring
                    else n_blocks * self.block_pool.block_size
                )
            else:
                prefill_len = self.cb.max_len
            now = self._clock()
            if req.enqueued_at is not None:
                self._h_queue.observe(now - req.enqueued_at)
                # consume the stamp: a preemption before the next admission
                # opens a NEW stint, and an unconsumed stamp marks a stint
                # that was never observed (see _preempt)
                req.enqueued_at = None
            self._m_admitted.inc()
            if self.tracer.enabled:
                self.tracer.instant("serve.admit", uid=req.uid,
                                    slot=slot.index, rows=rows)
            with self.tracer.span("serve.prefill", uid=req.uid, rows=rows):
                logits, cache1 = self.model.prefill(
                    self.params, jnp.asarray(tokens)[None], prefill_len, **fe
                )
                self._m_h2d.inc(len(tokens) * 4)
                if paged:
                    table = jnp.asarray(self._tables[slot.index, :n_blocks])
                    self._m_h2d.inc(n_blocks * 4)
                    self.pool = self._write_slot_paged(
                        self.pool, cache1, slot.index, table
                    )
                    self._rows[slot.index] = rows
                else:
                    self.pool = self._write_slot(self.pool, cache1, slot.index)
            self._m_d2h.inc(4)  # the admission-sampled token below
            tok = int(sample_token(
                logits[0, -1],
                self._request_key(req, len(req.generated_prefix)),
                self.cfg, self._serve_cfg, guard=self.guard,
            ))
            finished = self.scheduler.record_token(slot, tok)
            events.append(self._emit(slot, tok, finished))
            self._inputs[slot.index, 0] = tok
            if finished:
                self._finish(slot)

        # 1b. chunked prefill: stream this tick's prompt-token budget
        #     through staging slots; completed prefills join the decode
        #     batch below (same tick — with an infinite budget the timing
        #     matches the monolithic path exactly).
        if self._staging:
            events.extend(self._run_prefill_chunks())

        # 2. block upkeep: every active slot needs a home for this tick's
        #    KV write; exhaustion preempts latest-admitted work first.
        if paged:
            for slot in sorted(
                self.scheduler.active_slots, key=lambda s: s.request.uid
            ):
                if not slot.free:
                    self._ensure_decode_block(slot)

        # 3. one decode tick across the whole slot pool.
        active = self.scheduler.active_slots
        if active:
            # begin/end (not a span) keeps the long decode body unnested;
            # the uid list is only built when someone is recording
            if self.tracer.enabled:
                self.tracer.begin("serve.decode", tick=self.ticks,
                                  uids=[s.request.uid for s in active])
            s_count = self.cb.num_slots
            if paged:
                # flush dirty block-table rows: the only table bytes a
                # tick uploads (steady decode uploads none)
                for i in sorted(self._dirty_tables):
                    self._tables_dev = self._push_row(
                        self._tables_dev, jnp.int32(i),
                        jnp.asarray(self._tables[i]),
                    )
                    self._m_h2d.inc(self._slot_blocks * 4)
                self._dirty_tables.clear()
                tables = self._tables_dev
            else:
                tables = None
            if self._serve_cfg.temperature > 0.0:
                # full-pool uid/step vectors: free slots derive garbage
                # keys whose draws are discarded below
                uv = np.zeros(s_count, np.int32)
                sv = np.zeros(s_count, np.int32)
                for s in active:
                    uv[s.index] = s.request.uid
                    sv[s.index] = (
                        len(s.request.generated_prefix) + len(s.generated)
                    )
                uids, steps = jnp.asarray(uv), jnp.asarray(sv)
                self._m_h2d.inc(2 * s_count * 4)
            else:
                uids = steps = None
            # decode + sample fused in one program; ``last`` stays on
            # device unless the guard path needs it
            sampled_dev, last, self.pool = self._tick(
                self.params, self.pool, jnp.asarray(self._inputs),
                tables, uids, steps,
            )
            self._m_h2d.inc(self._inputs.size * 4)
            if paged:
                for slot in active:
                    self._rows[slot.index] += 1
            spec = self.cfg.softmax_spec
            if (
                self.guard is not None
                and self._serve_cfg.temperature > 0.0
                and self._serve_cfg.star_sampling
                and spec.kind != "exact"
            ):
                # guard needs concrete arrays: one batched eager softmax
                # over all active rows (a single oracle check per tick),
                # then the per-slot categorical draws — this path fetches
                # the logits row block, trading the single-transfer tick
                # for the host-side oracle comparison
                rows_ix = jnp.asarray([s.index for s in active])
                keys = jax.vmap(lambda u, i: jax.random.fold_in(
                    jax.random.fold_in(self._base_key, u), i))(
                        jnp.asarray([s.request.uid for s in active]),
                        jnp.asarray([
                            len(s.request.generated_prefix) + len(s.generated)
                            for s in active
                        ]))
                scaled = (
                    last[rows_ix].astype(jnp.float32)
                    / self._serve_cfg.temperature
                )
                probs = ops.softmax(scaled, spec, guard=self.guard)
                logp = jnp.log(jnp.maximum(probs, 1e-20))
                sampled = np.asarray(jax.vmap(
                    lambda k, lg: jax.random.categorical(k, lg, axis=-1)
                )(keys, logp)).astype(np.int32)
                self._m_d2h.inc(int(sampled.size) * 4 + len(active) * 4)
                toks = {s.index: int(t) for s, t in zip(active, sampled)}
            else:
                # the tick's single D2H transfer: the sampled-token vector
                sampled = np.asarray(sampled_dev)
                self._m_d2h.inc(int(sampled.size) * 4)
                toks = {s.index: int(sampled[s.index]) for s in active}
            if paged:
                impl = (
                    active_overrides("paged_attention").get("impl")
                    or self.cfg.paged_attention_spec.impl
                )
                pk = self.pool["layers"]["k"]
                quantized = "k_scale" in self.pool["layers"]
                self._m_gather.inc(pk.shape[0] * ops.paged_gather_bytes(
                    impl,
                    table_width=self._slot_blocks,
                    block_size=self.block_pool.block_size,
                    live_lens=np.minimum(self._rows, self._cache_t),
                    num_kv_heads=pk.shape[3],
                    head_dim=pk.shape[4],
                    dtype_bytes=pk.dtype.itemsize,
                    # per-layer K+V scale rows a quantized read touches
                    scale_bytes_per_block=(8 * pk.shape[3]) if quantized else 0,
                ))
            for slot in active:
                tok = toks[slot.index]
                finished = self.scheduler.record_token(slot, tok)
                events.append(self._emit(slot, tok, finished))
                self._inputs[slot.index, 0] = tok
                if finished:
                    self._finish(slot)
            if self.tracer.enabled:
                self.tracer.end("serve.decode")
            self.ticks += 1
        self._g_queue.set(len(self.scheduler.pending))
        self._g_active.set(len(self.scheduler.active_slots))
        self._g_jit.set(self.jit_cache_entries())
        if self.tracer.enabled:
            self.tracer.counter(
                "serve.sched",
                pending=len(self.scheduler.pending),
                active=len(self.scheduler.active_slots),
            )
            if paged:
                self.tracer.counter(
                    "kv.blocks", used=self.block_pool.used_blocks
                )
        return events

    # -- draining -----------------------------------------------------------

    def run(self, max_ticks: Optional[int] = None) -> Dict[int, List[int]]:
        """Drive ticks until every submitted request has finished; returns
        {uid: generated tokens}."""
        n = 0
        while not self.scheduler.done():
            self.step()
            n += 1
            if max_ticks is not None and n >= max_ticks and not self.scheduler.done():
                raise RuntimeError(f"engine did not drain within {max_ticks} ticks")
        return dict(self.scheduler.finished)

    def serve(
        self,
        prompts: Sequence[Sequence[int] | np.ndarray],
        max_new_tokens: int | Sequence[int],
        *,
        eos_id: Optional[int] = None,
    ) -> List[List[int]]:
        """Convenience: submit all prompts, drain, return outputs in order."""
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * len(prompts)
        uids = [
            self.submit(p, int(m), eos_id=eos_id)
            for p, m in zip(prompts, max_new_tokens)
        ]
        done = self.run()
        return [done[u] for u in uids]
