"""Paged KV-cache block pool: fixed-size token blocks + per-request tables.

The dense slot pool (PR 1) allocates ``num_slots * max_len`` KV rows up
front, so memory scales with worst-case capacity regardless of occupancy.
This module is the host-side half of the paged replacement (DESIGN.md §8):
the device cache becomes a flat pool of ``num_blocks`` blocks of
``block_size`` token rows each, and every request owns a *block table* — an
ordered list of block ids whose concatenation is that request's logical KV
buffer.  Decode cost and memory then scale with **live tokens**, not with
``num_slots * max_len`` — the STAR argument (attention state tiled into
crossbar-sized blocks instead of monolithic buffers) applied to serving.

Layout invariant: logical token row ``i`` of a request lives at row
``i % block_size`` of ``table[i // block_size]``.  Gathering a table and
concatenating its blocks therefore reproduces the dense per-slot cache row
bit-for-bit (up to masked garbage past the valid length), which is what
makes paged greedy decode token-identical to the dense path.

* **Block 0 is reserved** as the *scratch* block: free slots and unused
  table entries point at it, so the jitted decode step can scatter-write
  unconditionally — garbage lands in scratch and is never gathered as
  valid rows.  ``num_blocks`` therefore buys ``num_blocks - 1`` usable
  blocks.
* **Free list** — allocate/append pop from it, release pushes back.
  Exhaustion raises :class:`PoolExhausted`; the engine's policy on that
  signal (preempt the lowest-priority slot and requeue it) lives in
  ``serve/engine.py``, not here.
* **Copy-on-fork** — ``fork`` shares the parent's blocks with a child
  table under refcounting (beam / parallel-sampling decode shares the
  whole prompt prefix for free).  A write to a *shared* block must first
  privatize it: ``ensure_writable`` returns the ``(src, dst)`` block copy
  the device cache has to perform.  Append-only decode only ever writes
  the last block, but a sliding-window *ring* wraps in place and can
  write any block of the table, so ``ensure_writable`` takes the index
  of the block actually being written (default: the last).
* **Prefix sharing** — :class:`PrefixCache` is a radix/trie index keyed
  on ``block_size``-token chunks of the token-id stream.  Each trie node
  *pins* one pool block (``pin`` / ``unpin``: a bare refcount with no
  table), and ``adopt`` grafts matched blocks into a new request's table
  (refcount++), so admission skips prefill for the shared prefix
  entirely.  Eviction is LRU over leaf nodes whose block refcount is 1
  (the trie pin is the only owner) — blocks shared with a live table are
  never evicted.

Pure host-side bookkeeping (no jax imports) — same layering as
:class:`~repro.serve.scheduler.SlotScheduler`.  Passing a
:class:`~repro.obs.metrics.MetricsRegistry` (``metrics=``) publishes
``kv.blocks.allocated`` / ``kv.blocks.freed`` counters and a
``kv.blocks.used`` gauge; with ``metrics=None`` the allocator records
nothing (DESIGN.md §10).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry

SCRATCH_BLOCK = 0  # reserved id: free-slot / padding writes land here

# Valid page-pool storage layouts — a jax-free mirror of
# ``repro.core.kvquant.KV_DTYPES`` (this module must stay importable
# without jax; the parity of the two tuples is pinned by the test suite).
KV_DTYPES = ("fp32", "int8", "fp8_e4m3")


def bucket_blocks(n: int, cap: int) -> int:
    """Round a block count up to the next power of two, clamped to ``cap``.

    Jit cache keys include operand shapes, so the engine's admission write
    (``write_slot_paged``) would retrace per distinct (prefill length,
    table width) pair — O(n) programs under mixed-length traffic.  Padding
    the admission table to the bucketed width (extra entries point at the
    scratch block, extra prefill rows are masked garbage) bounds the
    variant count to O(log cap) without changing a single gathered row
    (DESIGN.md §11 retrace-bucketing policy).
    """
    if n <= 0:
        return min(1, cap)
    if n >= cap:
        return cap
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class PoolExhausted(RuntimeError):
    """The free list cannot satisfy an allocation.

    Carries enough context for an actionable message; the engine catches
    this to drive preemption rather than surfacing it to callers.
    """


class BlockPool:
    """Fixed-size block allocator with per-request tables and refcounts."""

    def __init__(
        self,
        num_blocks: int,
        block_size: int,
        *,
        kv_dtype: str = "fp32",
        metrics: Optional[MetricsRegistry] = None,
    ):
        if num_blocks < 2:
            raise ValueError(
                f"num_blocks must be >= 2 (block 0 is the reserved scratch "
                f"block), got {num_blocks}"
            )
        if block_size <= 0:
            raise ValueError(f"block_size must be positive, got {block_size}")
        if kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}"
            )
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.kv_dtype = kv_dtype
        # Host-side mirror of the device scale pages (DESIGN.md §13): a
        # quantized pool carries one scale row per allocated block, sharing
        # the block's lifecycle exactly — handed out with the block, retired
        # when the block returns to the free list.  The property suite pins
        # ``_scale_pages == set(_refcount)`` through every op sequence.
        self._scale_pages: set = set()
        # LIFO free list: hot blocks are reused first (better locality and
        # the stale-reuse tests exercise the hardest path constantly)
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._refcount: Dict[int, int] = {}
        self._tables: Dict[int, List[int]] = {}
        self._m_alloc = metrics.counter(
            "kv.blocks.allocated", "blocks handed out (allocate/append/CoW)"
        ) if metrics is not None else None
        self._m_freed = metrics.counter(
            "kv.blocks.freed", "blocks returned to the free list"
        ) if metrics is not None else None
        self._m_used = metrics.gauge(
            "kv.blocks.used", "distinct allocated blocks right now"
        ) if metrics is not None else None

    def _track(self, allocated: int = 0, freed: int = 0) -> None:
        if self._m_used is None:
            return
        if allocated:
            self._m_alloc.inc(allocated)
        if freed:
            self._m_freed.inc(freed)
        self._m_used.set(self.used_blocks)

    # -- capacity ------------------------------------------------------------

    @property
    def usable_blocks(self) -> int:
        """Blocks a single request could ever own (scratch excluded)."""
        return self.num_blocks - 1

    @property
    def quantized(self) -> bool:
        return self.kv_dtype != "fp32"

    def has_scale_page(self, block: int) -> bool:
        """True when the block currently owns a live scale page (quantized
        pools only; always False at fp32)."""
        return block in self._scale_pages

    def _page_out(self, block: int) -> None:
        if self.kv_dtype != "fp32":
            self._scale_pages.add(block)

    def _page_retire(self, block: int) -> None:
        self._scale_pages.discard(block)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Distinct allocated blocks (shared blocks counted once)."""
        return self.usable_blocks - len(self._free)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def blocks_for_tokens(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` rows."""
        return -(-tokens // self.block_size)

    # -- tables --------------------------------------------------------------

    def table(self, uid: int) -> List[int]:
        """The request's block table (a copy: callers cannot corrupt it)."""
        return list(self._tables[uid])

    def owners(self) -> List[int]:
        return sorted(self._tables)

    def allocate(self, uid: int, n: int) -> List[int]:
        """Create a table of ``n`` fresh blocks for ``uid``."""
        if uid in self._tables:
            raise ValueError(f"uid {uid} already owns a block table")
        if n > len(self._free):
            raise PoolExhausted(
                f"request {uid} needs {n} blocks but only "
                f"{len(self._free)} of {self.usable_blocks} are free"
            )
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._refcount[b] = 1
            self._page_out(b)
        self._tables[uid] = blocks
        self._track(allocated=n)
        return list(blocks)

    def append(self, uid: int) -> int:
        """Grow ``uid``'s table by one fresh block; returns its id."""
        if uid not in self._tables:
            raise ValueError(f"uid {uid} owns no block table")
        if not self._free:
            raise PoolExhausted(
                f"request {uid} needs one more block but the pool is "
                f"exhausted ({self.usable_blocks} blocks, all in use)"
            )
        b = self._free.pop()
        self._refcount[b] = 1
        self._page_out(b)
        self._tables[uid].append(b)
        self._track(allocated=1)
        return b

    def release(self, uid: int) -> List[int]:
        """Drop ``uid``'s table; blocks return to the free list when their
        refcount hits zero (forked children keep shared blocks alive)."""
        blocks = self._tables.pop(uid)
        freed = []
        for b in blocks:
            self._refcount[b] -= 1
            if self._refcount[b] == 0:
                del self._refcount[b]
                self._free.append(b)
                self._page_retire(b)
                freed.append(b)
        self._track(freed=len(freed))
        return freed

    # -- copy-on-fork ---------------------------------------------------------

    def fork(self, parent_uid: int, child_uid: int) -> List[int]:
        """Share the parent's blocks with ``child_uid`` (refcount++)."""
        if child_uid in self._tables:
            raise ValueError(f"uid {child_uid} already owns a block table")
        blocks = self._tables[parent_uid]
        for b in blocks:
            self._refcount[b] += 1
        self._tables[child_uid] = list(blocks)
        return list(blocks)

    def ensure_writable(
        self, uid: int, block_index: Optional[int] = None
    ) -> Optional[Tuple[int, int]]:
        """Privatize the table entry about to be written (copy-on-write).

        ``block_index`` is the position *within the table* of the block the
        next device write lands in — ``table[row // block_size]`` for a
        write to logical row ``row``.  The default (``None``) privatizes the
        last entry, which is correct for append-only decode; a sliding-window
        ring wraps in place and can write *any* entry, so ring callers must
        pass the wrapped index or risk corrupting a fork sibling's KV.

        Returns ``(src, dst)`` when the block was shared — the caller must
        copy the device rows ``src -> dst`` before writing — or ``None``
        when the block was already exclusive.
        """
        table = self._tables[uid]
        idx = len(table) - 1 if block_index is None else block_index
        src = table[idx]
        if self._refcount[src] == 1:
            return None
        if not self._free:
            raise PoolExhausted(
                f"request {uid} needs a private copy of shared block {src} "
                f"but the pool is exhausted"
            )
        dst = self._free.pop()
        self._refcount[src] -= 1
        self._refcount[dst] = 1
        # the device-side copy_block duplicates src's codes AND its scale
        # row into dst, so dst's page is live the moment it is handed out
        self._page_out(dst)
        table[idx] = dst
        self._track(allocated=1)
        return src, dst

    def refcount(self, block: int) -> int:
        return self._refcount.get(block, 0)

    # -- prefix sharing -------------------------------------------------------

    def adopt(self, uid: int, blocks: List[int]) -> List[int]:
        """Create ``uid``'s table from *existing* blocks (refcount++).

        The prefix-cache admission path: the trie matched ``blocks`` for the
        request's cached prefix, and the table starts out sharing them
        exactly like a fork shares a parent's prompt.  The caller appends
        fresh blocks for the uncached suffix afterwards.
        """
        if uid in self._tables:
            raise ValueError(f"uid {uid} already owns a block table")
        for b in blocks:
            if self._refcount.get(b, 0) < 1:
                raise ValueError(f"cannot adopt unallocated block {b}")
        for b in blocks:
            self._refcount[b] += 1
        self._tables[uid] = list(blocks)
        return list(blocks)

    def pin(self, block: int) -> None:
        """Take a bare (table-less) reference on an allocated block.

        Trie nodes pin the block they map to so it survives the owning
        request's release; a pinned block is freed only when ``unpin``
        drops the final reference.
        """
        if self._refcount.get(block, 0) < 1:
            raise ValueError(f"cannot pin unallocated block {block}")
        self._refcount[block] += 1

    def unpin(self, block: int) -> bool:
        """Drop a pin; returns True when the block went back to the free
        list (the pin was the last reference)."""
        if self._refcount.get(block, 0) < 1:
            raise ValueError(f"cannot unpin unallocated block {block}")
        self._refcount[block] -= 1
        if self._refcount[block] == 0:
            del self._refcount[block]
            self._free.append(block)
            self._page_retire(block)
            self._track(freed=1)
            return True
        return False


class _TrieNode:
    """One ``block_size``-token chunk of some cached prefix → one block."""

    __slots__ = ("chunk", "block", "parent", "children", "touch")

    def __init__(self, chunk, block, parent):
        self.chunk = chunk
        self.block = block
        self.parent = parent
        self.children: Dict[tuple, "_TrieNode"] = {}
        self.touch = 0


class PrefixCache:
    """Radix/trie index over cached prompt prefixes, one block per node.

    Keys are ``block_size``-token chunks of the token-id stream, so a path
    from the root spells out a prefix in whole blocks and each node pins the
    pool block holding that chunk's KV rows.  ``lookup`` walks the longest
    cached prefix of a new request (LRU-touching the path) and ``insert``
    grafts a finished prefill's *full* blocks in (partial tail blocks are
    never shared — the owner keeps appending into them).

    Eviction (``evict_one``) removes the least-recently-touched **leaf**
    whose block refcount is 1, i.e. the trie pin is the only owner: interior
    nodes are kept while descendants need the path, and blocks shared with a
    live request table are never reclaimed.  The engine calls it on demand
    when the free list runs dry, before falling back to preemption.
    """

    def __init__(
        self,
        pool: BlockPool,
        *,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.pool = pool
        self.block_size = pool.block_size
        self.root = _TrieNode(None, -1, None)
        self.hits = 0
        self.tokens_saved = 0
        self.evicted = 0
        self._clock = 0
        self._nodes = 0
        self._m_hits = metrics.counter(
            "kv.prefix.hits", "admissions that matched a cached prefix"
        ) if metrics is not None else None
        self._m_saved = metrics.counter(
            "kv.prefix.tokens_saved", "prompt tokens served from cached blocks"
        ) if metrics is not None else None
        self._m_evicted = metrics.counter(
            "kv.prefix.evicted", "trie nodes evicted (blocks unpinned)"
        ) if metrics is not None else None

    def __len__(self) -> int:
        return self._nodes

    def _chunk(self, tokens, i: int) -> tuple:
        bs = self.block_size
        return tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])

    def lookup(self, tokens) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens`` → (block ids, rows matched).

        At most ``(len(tokens) - 1) // block_size`` chunks match: at least
        one suffix token always goes through prefill so admission has fresh
        logits to sample the first output token from.
        """
        max_chunks = max(0, (len(tokens) - 1) // self.block_size)
        node, blocks = self.root, []
        for i in range(max_chunks):
            child = node.children.get(self._chunk(tokens, i))
            if child is None:
                break
            self._clock += 1
            child.touch = self._clock
            blocks.append(child.block)
            node = child
        if blocks:
            self.hits += 1
            self.tokens_saved += len(blocks) * self.block_size
            if self._m_hits is not None:
                self._m_hits.inc()
                self._m_saved.inc(len(blocks) * self.block_size)
        return blocks, len(blocks) * self.block_size

    def insert(self, tokens, table: List[int]) -> int:
        """Index a prefilled request's full blocks; returns nodes added.

        ``table[i]`` must hold rows ``[i*bs, (i+1)*bs)`` of ``tokens``.
        Chunks already present keep their existing (content-identical)
        block; new nodes pin the donor's block so it outlives the donor.
        """
        n = min(len(tokens) // self.block_size, len(table))
        node, added = self.root, 0
        for i in range(n):
            chunk = self._chunk(tokens, i)
            child = node.children.get(chunk)
            if child is None:
                child = _TrieNode(chunk, table[i], node)
                node.children[chunk] = child
                self.pool.pin(table[i])
                self._nodes += 1
                added += 1
            self._clock += 1
            child.touch = self._clock
            node = child
        return added

    def evict_one(self) -> bool:
        """Unpin the LRU evictable leaf; True when a block was reclaimed."""
        best = None
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            if nd.children:
                stack.extend(nd.children.values())
            elif self.pool.refcount(nd.block) == 1:
                if best is None or nd.touch < best.touch:
                    best = nd
        if best is None:
            return False
        del best.parent.children[best.chunk]
        self.pool.unpin(best.block)
        self._nodes -= 1
        self.evicted += 1
        if self._m_evicted is not None:
            self._m_evicted.inc()
        return True

    def clear(self) -> int:
        """Drop every node and pin (post-order); returns nodes removed."""
        removed = 0
        stack = [(self.root, iter(list(self.root.children.values())))]
        while stack:
            node, it = stack[-1]
            child = next(it, None)
            if child is not None:
                stack.append((child, iter(list(child.children.values()))))
                continue
            stack.pop()
            if node is not self.root:
                self.pool.unpin(node.block)
                removed += 1
        self.root.children.clear()
        self._nodes = 0
        return removed
