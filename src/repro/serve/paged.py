"""Paged KV-cache block pool: fixed-size token blocks + per-request tables.

The dense slot pool (PR 1) allocates ``num_slots * max_len`` KV rows up
front, so memory scales with worst-case capacity regardless of occupancy.
This module is the host-side half of the paged replacement (DESIGN.md §8):
the device cache becomes a flat pool of ``num_blocks`` blocks of
``block_size`` token rows each, and every request owns a *block table* — an
ordered list of block ids whose concatenation is that request's logical KV
buffer.  Decode cost and memory then scale with **live tokens**, not with
``num_slots * max_len`` — the STAR argument (attention state tiled into
crossbar-sized blocks instead of monolithic buffers) applied to serving.

Layout invariant: logical token row ``i`` of a request lives at row
``i % block_size`` of ``table[i // block_size]``.  Gathering a table and
concatenating its blocks therefore reproduces the dense per-slot cache row
bit-for-bit (up to masked garbage past the valid length), which is what
makes paged greedy decode token-identical to the dense path.

* **Block 0 is reserved** as the *scratch* block: free slots and unused
  table entries point at it, so the jitted decode step can scatter-write
  unconditionally — garbage lands in scratch and is never gathered as
  valid rows.  ``num_blocks`` therefore buys ``num_blocks - 1`` usable
  blocks.
* **Free list** — allocate/append pop from it, release pushes back.
  Exhaustion raises :class:`PoolExhausted`; the engine's policy on that
  signal (preempt the lowest-priority slot and requeue it) lives in
  ``serve/engine.py``, not here.
* **Copy-on-fork** — ``fork`` shares the parent's blocks with a child
  table under refcounting (beam / parallel-sampling decode shares the
  whole prompt prefix for free).  A write to a *shared* block must first
  privatize it: ``ensure_writable`` returns the ``(src, dst)`` block copy
  the device cache has to perform.  Only the last block is ever written
  in append-only decode, so one copy per fork divergence suffices.

Pure host-side bookkeeping (no jax imports) — same layering as
:class:`~repro.serve.scheduler.SlotScheduler`.  Passing a
:class:`~repro.obs.metrics.MetricsRegistry` (``metrics=``) publishes
``kv.blocks.allocated`` / ``kv.blocks.freed`` counters and a
``kv.blocks.used`` gauge; with ``metrics=None`` the allocator records
nothing (DESIGN.md §10).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry

SCRATCH_BLOCK = 0  # reserved id: free-slot / padding writes land here


def bucket_blocks(n: int, cap: int) -> int:
    """Round a block count up to the next power of two, clamped to ``cap``.

    Jit cache keys include operand shapes, so the engine's admission write
    (``write_slot_paged``) would retrace per distinct (prefill length,
    table width) pair — O(n) programs under mixed-length traffic.  Padding
    the admission table to the bucketed width (extra entries point at the
    scratch block, extra prefill rows are masked garbage) bounds the
    variant count to O(log cap) without changing a single gathered row
    (DESIGN.md §11 retrace-bucketing policy).
    """
    if n <= 0:
        return min(1, cap)
    if n >= cap:
        return cap
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class PoolExhausted(RuntimeError):
    """The free list cannot satisfy an allocation.

    Carries enough context for an actionable message; the engine catches
    this to drive preemption rather than surfacing it to callers.
    """


class BlockPool:
    """Fixed-size block allocator with per-request tables and refcounts."""

    def __init__(
        self,
        num_blocks: int,
        block_size: int,
        *,
        metrics: Optional[MetricsRegistry] = None,
    ):
        if num_blocks < 2:
            raise ValueError(
                f"num_blocks must be >= 2 (block 0 is the reserved scratch "
                f"block), got {num_blocks}"
            )
        if block_size <= 0:
            raise ValueError(f"block_size must be positive, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list: hot blocks are reused first (better locality and
        # the stale-reuse tests exercise the hardest path constantly)
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._refcount: Dict[int, int] = {}
        self._tables: Dict[int, List[int]] = {}
        self._m_alloc = metrics.counter(
            "kv.blocks.allocated", "blocks handed out (allocate/append/CoW)"
        ) if metrics is not None else None
        self._m_freed = metrics.counter(
            "kv.blocks.freed", "blocks returned to the free list"
        ) if metrics is not None else None
        self._m_used = metrics.gauge(
            "kv.blocks.used", "distinct allocated blocks right now"
        ) if metrics is not None else None

    def _track(self, allocated: int = 0, freed: int = 0) -> None:
        if self._m_used is None:
            return
        if allocated:
            self._m_alloc.inc(allocated)
        if freed:
            self._m_freed.inc(freed)
        self._m_used.set(self.used_blocks)

    # -- capacity ------------------------------------------------------------

    @property
    def usable_blocks(self) -> int:
        """Blocks a single request could ever own (scratch excluded)."""
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Distinct allocated blocks (shared blocks counted once)."""
        return self.usable_blocks - len(self._free)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def blocks_for_tokens(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` rows."""
        return -(-tokens // self.block_size)

    # -- tables --------------------------------------------------------------

    def table(self, uid: int) -> List[int]:
        """The request's block table (a copy: callers cannot corrupt it)."""
        return list(self._tables[uid])

    def owners(self) -> List[int]:
        return sorted(self._tables)

    def allocate(self, uid: int, n: int) -> List[int]:
        """Create a table of ``n`` fresh blocks for ``uid``."""
        if uid in self._tables:
            raise ValueError(f"uid {uid} already owns a block table")
        if n > len(self._free):
            raise PoolExhausted(
                f"request {uid} needs {n} blocks but only "
                f"{len(self._free)} of {self.usable_blocks} are free"
            )
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._refcount[b] = 1
        self._tables[uid] = blocks
        self._track(allocated=n)
        return list(blocks)

    def append(self, uid: int) -> int:
        """Grow ``uid``'s table by one fresh block; returns its id."""
        if uid not in self._tables:
            raise ValueError(f"uid {uid} owns no block table")
        if not self._free:
            raise PoolExhausted(
                f"request {uid} needs one more block but the pool is "
                f"exhausted ({self.usable_blocks} blocks, all in use)"
            )
        b = self._free.pop()
        self._refcount[b] = 1
        self._tables[uid].append(b)
        self._track(allocated=1)
        return b

    def release(self, uid: int) -> List[int]:
        """Drop ``uid``'s table; blocks return to the free list when their
        refcount hits zero (forked children keep shared blocks alive)."""
        blocks = self._tables.pop(uid)
        freed = []
        for b in blocks:
            self._refcount[b] -= 1
            if self._refcount[b] == 0:
                del self._refcount[b]
                self._free.append(b)
                freed.append(b)
        self._track(freed=len(freed))
        return freed

    # -- copy-on-fork ---------------------------------------------------------

    def fork(self, parent_uid: int, child_uid: int) -> List[int]:
        """Share the parent's blocks with ``child_uid`` (refcount++)."""
        if child_uid in self._tables:
            raise ValueError(f"uid {child_uid} already owns a block table")
        blocks = self._tables[parent_uid]
        for b in blocks:
            self._refcount[b] += 1
        self._tables[child_uid] = list(blocks)
        return list(blocks)

    def ensure_writable(self, uid: int) -> Optional[Tuple[int, int]]:
        """Privatize the request's *last* block before an append-only write.

        Returns ``(src, dst)`` when the block was shared — the caller must
        copy the device rows ``src -> dst`` before writing — or ``None``
        when the block was already exclusive.
        """
        table = self._tables[uid]
        last = table[-1]
        if self._refcount[last] == 1:
            return None
        if not self._free:
            raise PoolExhausted(
                f"request {uid} needs a private copy of shared block {last} "
                f"but the pool is exhausted"
            )
        dst = self._free.pop()
        self._refcount[last] -= 1
        self._refcount[dst] = 1
        table[-1] = dst
        self._track(allocated=1)
        return last, dst

    def refcount(self, block: int) -> int:
        return self._refcount.get(block, 0)
