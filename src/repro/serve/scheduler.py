"""Slot scheduler for continuous batching.

The serving pool is a fixed set of ``num_slots`` KV-cache rows.  Each slot
walks a three-state lifecycle (PREFILLING only under chunked prefill):

    FREE ──admit──> [PREFILLING ──chunks done──>] ACTIVE ──finish──> FREE
     ^                                                                │
     └──────────────────── (immediately reusable) ────────────────────┘

With chunked prefill (DESIGN.md §12) an admitted slot is *bound* but not
yet decoding: the engine feeds its prompt through in budgeted chunks over
several ticks while ACTIVE slots keep decoding.  ``Slot.prefilling`` marks
that window; ``active_slots`` excludes such slots (they have no decode row
yet) and ``occupied_slots`` includes them (they hold resources and are
preemptible).

* **Submission** (`submit`) appends a :class:`Request` to a FIFO pending
  queue.  The queue is unbounded — backpressure happens at *admission*, not
  submission: requests wait in line until a slot frees up, so a full pool
  never drops or reorders work.
* **Admission** (`admit`) pops pending requests into FREE slots (FIFO; at
  most one request per free slot per tick).  The engine prefills each
  admitted request into its slot's cache row while decode keeps running for
  the slots that were already ACTIVE — this is the continuous-batching
  analogue of the paper's fine-grained pipeline: new work slides into the
  engine between decode ticks instead of waiting for the whole batch to
  drain.
* **Eviction / completion** (`retire`): a slot finishes when its request has
  produced ``max_new_tokens`` tokens or sampled ``eos_id``.  `retire` frees
  the slot immediately; the engine zeroes the slot's length counter so the
  stale KV rows are masked out (they are overwritten wholesale by the next
  admission).
* **Preemption** (`preempt`): the paged-KV engine may evict an unfinished
  request when the block pool runs dry.  The request keeps everything it
  generated (``Request.generated_prefix``) and returns to the *front* of
  the pending queue, so FIFO priority is preserved and the eventual output
  is identical to an uncontended run.

The scheduler is pure host-side bookkeeping — it never touches jax arrays —
so it is trivially reusable by any engine that exposes "prefill into row i"
and "decode all rows" primitives.  See DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is a 1-D int token array; ``max_new_tokens`` bounds the
    generation; ``eos_id`` (optional) stops it early.  ``arrival_time`` is
    only used by benchmarks / traces — the scheduler itself is clockless and
    admits in submission order.
    """

    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_time: float = 0.0
    # Tokens generated before a preemption (paged KV pools): a preempted
    # request re-prefills ``prompt + generated_prefix`` on re-admission and
    # resumes mid-stream — budget, PRNG indices, and the finished output
    # all count these tokens, so preemption is invisible to the caller.
    generated_prefix: List[int] = dataclasses.field(default_factory=list)
    # Lifecycle timestamps, stamped by the engine's (injectable) clock for
    # the obs layer (DESIGN.md §10).  ``enqueued_at`` restarts on each
    # preemption (queue-wait counts every stint in the pending queue);
    # ``submit_time`` / ``first_token_time`` never do (TTFT is end-to-end).
    submit_time: Optional[float] = None
    enqueued_at: Optional[float] = None
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None

    def __post_init__(self) -> None:
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError(f"prompt must be a non-empty 1-D array, got {self.prompt.shape}")
        if self.max_new_tokens <= 0:
            raise ValueError("max_new_tokens must be positive")


@dataclasses.dataclass
class Slot:
    """One KV-cache row of the pool and the request currently bound to it."""

    index: int
    request: Optional[Request] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    # True while the engine is still streaming prompt chunks into the
    # slot's cache row (chunked prefill): bound, holds blocks, but not yet
    # part of the decode batch.
    prefilling: bool = False

    @property
    def free(self) -> bool:
        return self.request is None

    def bind(self, request: Request) -> None:
        assert self.free, f"slot {self.index} is busy"
        self.request = request
        self.generated = []
        self.prefilling = False

    def release(self) -> Request:
        assert self.request is not None
        req, self.request = self.request, None
        self.prefilling = False
        return req


class SlotScheduler:
    """Admission + retirement over a fixed slot pool (host-side only)."""

    def __init__(self, num_slots: int):
        if num_slots <= 0:
            raise ValueError("num_slots must be positive")
        self.slots: List[Slot] = [Slot(i) for i in range(num_slots)]
        self.pending: Deque[Request] = deque()
        self.finished: Dict[int, List[int]] = {}
        self._next_uid = 0

    # -- submission ---------------------------------------------------------

    def submit(
        self,
        prompt: Sequence[int] | np.ndarray,
        max_new_tokens: int,
        *,
        eos_id: Optional[int] = None,
        arrival_time: float = 0.0,
    ) -> int:
        """Queue a request; returns its uid.  Never blocks: a full pool only
        delays *admission* (FIFO), not submission."""
        uid = self._next_uid
        self._next_uid += 1
        self.pending.append(
            Request(uid, np.asarray(prompt, np.int32), max_new_tokens,
                    eos_id=eos_id, arrival_time=arrival_time)
        )
        return uid

    # -- admission ----------------------------------------------------------

    def free_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.free]

    def admit(self) -> List[Slot]:
        """Bind pending requests to free slots (FIFO).  Returns the slots
        admitted this tick, for the engine to prefill."""
        admitted: List[Slot] = []
        for slot in self.slots:
            if not self.pending:
                break
            if slot.free:
                slot.bind(self.pending.popleft())
                admitted.append(slot)
        return admitted

    # -- progress / completion ----------------------------------------------

    def record_token(self, slot: Slot, token: int) -> bool:
        """Append a sampled token to the slot; returns True if the request
        just finished (budget exhausted or EOS sampled).  Tokens produced
        before a preemption (``generated_prefix``) count against the
        budget."""
        req = slot.request
        assert req is not None
        slot.generated.append(int(token))
        if req.eos_id is not None and int(token) == req.eos_id:
            return True
        return len(req.generated_prefix) + len(slot.generated) >= req.max_new_tokens

    def retire(self, slot: Slot) -> Request:
        """Finish the slot's request and free the slot for immediate reuse."""
        req = slot.request
        self.finished[req.uid] = list(req.generated_prefix) + list(slot.generated)
        return slot.release()

    def preempt(self, slot: Slot) -> Request:
        """Evict an unfinished request: fold its generated tokens into the
        request's ``generated_prefix`` and requeue it at the *front* of the
        pending queue (it keeps its FIFO priority).  The engine owns the
        policy of *which* slot to preempt (paged pool exhaustion) and must
        release the slot's KV resources itself."""
        req = slot.request
        assert req is not None
        req.generated_prefix = list(req.generated_prefix) + list(slot.generated)
        slot.release()
        self.pending.appendleft(req)
        return req

    # -- introspection ------------------------------------------------------

    @property
    def active_slots(self) -> List[Slot]:
        """Slots in the decode batch (bound and done prefilling)."""
        return [s for s in self.slots if not s.free and not s.prefilling]

    @property
    def prefilling_slots(self) -> List[Slot]:
        """Bound slots still streaming prompt chunks (chunked prefill)."""
        return [s for s in self.slots if not s.free and s.prefilling]

    @property
    def occupied_slots(self) -> List[Slot]:
        """Every bound slot — decoding or prefilling; the preemption
        candidate set (both kinds hold KV resources)."""
        return [s for s in self.slots if not s.free]

    def done(self) -> bool:
        return not self.pending and all(s.free for s in self.slots)
