"""End-to-end driver: train a ~100M-parameter STAR-attention LM for a few
hundred steps on the synthetic pipeline, with checkpointing.

Full run (~100M params, a few hundred steps — takes a while on 1 CPU):
    PYTHONPATH=src python examples/train_lm_star.py --full
Default quick run (scaled-down model, same code path, ~1 minute):
    PYTHONPATH=src python examples/train_lm_star.py
"""

import argparse
import dataclasses
import tempfile

from repro.configs.base import ModelConfig
from repro.train.loop import LoopConfig, run_train
from repro.train.step import TrainConfig


def model_100m() -> ModelConfig:
    # ~103M params: 12L, d=640, untied embeddings, 32k vocab
    return ModelConfig(
        name="star-lm-100m", family="dense",
        num_layers=12, d_model=640, num_heads=10, num_kv_heads=5,
        d_ff=2560, vocab_size=32768,
        softmax_kind="star_ste",  # quantization-aware training on STAR
        param_dtype="float32", compute_dtype="float32", remat=False,
    )


def model_small() -> ModelConfig:
    return dataclasses.replace(
        model_100m(), num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
        d_ff=1024, vocab_size=2048, name="star-lm-small",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params, 300 steps")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_small()
    steps = args.steps or (300 if args.full else 60)
    batch, seq = (8, 512) if args.full else (8, 128)

    from repro.models.param import count_params
    from repro.models.registry import build_model
    n = count_params(build_model(cfg).param_specs())
    print(f"model: {cfg.name}  params: {n/1e6:.1f}M  softmax: {cfg.softmax_kind} "
          f"({cfg.softmax_format.short_name()})")

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="star_lm_")
    res = run_train(
        cfg,
        TrainConfig(peak_lr=6e-4, warmup_steps=max(10, steps // 20), total_steps=steps),
        LoopConfig(num_steps=steps, batch=batch, seq_len=seq,
                   ckpt_dir=ckpt, ckpt_every=max(25, steps // 4), log_every=10),
    )
    first = sum(h["loss"] for h in res["history"][:5]) / 5
    last = sum(h["loss"] for h in res["history"][-5:]) / 5
    print(f"\nloss {first:.3f} -> {last:.3f} over {res['final_step']} steps "
          f"(checkpoints in {ckpt})")
    assert last < first, "training must make progress"


if __name__ == "__main__":
    main()
