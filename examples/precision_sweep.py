"""The paper's precision trade-off, interactively: sweep softmax bitwidths
on a trained model and print the accuracy/error landscape + a calibration
suggestion for your own logits (repro.core.precision.calibrate_format).

    PYTHONPATH=src python examples/precision_sweep.py
"""

import jax.numpy as jnp
import numpy as np

from benchmarks.accuracy_bitwidth import evaluate, gen_data, train
from repro.core.attention import SoftmaxConfig
from repro.core.fixedpoint import FixedPointFormat
from repro.core.precision import calibrate_format
from repro.core.star_softmax import exact_softmax, star_softmax


def main():
    print("training the induction-retrieval classifier (exact softmax)...")
    params = train(steps=300)

    print(f"{'format':>12s} {'accuracy':>9s} {'softmax err':>12s}")
    rng = np.random.default_rng(0)
    probe = jnp.asarray(rng.normal(size=(64, 128)) * 5, jnp.float32)
    for name, fmt in [
        ("exact", None),
        ("9b (6i.3f)", FixedPointFormat(6, 3)),
        ("8b (6i.2f)", FixedPointFormat(6, 2)),
        ("7b (5i.2f)", FixedPointFormat(5, 2)),
        ("5b (4i.1f)", FixedPointFormat(4, 1)),
        ("3b (2i.1f)", FixedPointFormat(2, 1)),
        ("2b (1i.1f)", FixedPointFormat(1, 1)),
    ]:
        if fmt is None:
            acc = evaluate(params, SoftmaxConfig(kind="exact"))
            err = 0.0
        else:
            acc = evaluate(params, SoftmaxConfig(kind="star", fmt=fmt))
            err = float(jnp.max(jnp.abs(
                star_softmax(probe, fmt) - exact_softmax(probe))))
        print(f"{name:>12s} {acc*100:8.1f}% {err:12.4f}")

    # calibration on observed logits (the paper's per-dataset procedure)
    z = probe - jnp.max(probe, axis=-1, keepdims=True)
    fmt = calibrate_format(np.asarray(z))
    print(f"\ncalibrate_format on these logits -> {fmt.short_name()} "
          f"(paper's CNEWS/MRPC/CoLA formats were derived this way)")


if __name__ == "__main__":
    main()
