"""Quickstart: the STAR softmax engine in four acts.

    PYTHONPATH=src python examples/quickstart.py

1. drop-in quantized softmax (the paper's engine),
2. STAR attention (two-pass and vector-pipelined forms agree),
3. the Pallas kernel matches both,
4. one dispatch layer (repro.ops) swaps between all of them.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops
from repro.core import (
    DEFAULT_FORMAT, FORMAT_MRPC, STAR_SOFTMAX, EXACT_SOFTMAX,
    attention, blocked_attention, exact_softmax, star_softmax,
)

rng = np.random.default_rng(0)

# --- 1. the softmax engine ---------------------------------------------------
x = jnp.asarray(rng.normal(size=(4, 128)) * 4, jnp.float32)
p_exact = exact_softmax(x)
p_star = star_softmax(x, DEFAULT_FORMAT, mode="histogram")  # counter+VMM form
print("STAR softmax (8-bit CNEWS format)")
print("  max |p_star - p_exact| =", float(jnp.max(jnp.abs(p_star - p_exact))))
print("  rows sum to", np.asarray(p_star.sum(-1))[:2], "...")
p9 = star_softmax(x, FORMAT_MRPC)
print("  9-bit error:", float(jnp.max(jnp.abs(p9 - p_exact))), "(tighter)")

# --- 2. STAR attention: two-pass vs vector-grained pipeline -------------------
q = jnp.asarray(rng.normal(size=(2, 64, 8, 32)), jnp.float32)
k = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.float32)  # GQA 8:2
v = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.float32)
two_pass = attention(q, k, v, softmax=STAR_SOFTMAX, causal=True)
pipelined = blocked_attention(q, k, v, softmax=STAR_SOFTMAX, causal=True, block_size=16)
print("\nSTAR attention")
print("  two-pass vs vector-pipeline:", float(jnp.max(jnp.abs(two_pass - pipelined))),
      "(integer-grid arithmetic makes the online form exact)")
exact = attention(q, k, v, softmax=EXACT_SOFTMAX, causal=True)
print("  STAR vs exact attention:   ", float(jnp.max(jnp.abs(two_pass - exact))))

# --- 3. the fused Pallas kernel ----------------------------------------------
flash = ops.AttentionSpec(impl="pallas", causal=True, block_q=32, block_k=32)
kern = ops.attention(q, k, v, flash)
print("\nflash_star Pallas kernel (interpret =", ops.default_interpret(), "here)")
print("  kernel vs two-pass:", float(jnp.max(jnp.abs(kern - two_pass))))
kern8 = ops.attention(q, k, v, flash, pv_int8=True)
print("  int8 P*V variant err:", float(jnp.max(jnp.abs(kern8 - exact))),
      "(beyond-paper: 2x MXU throughput)")

# --- 4. the dispatch layer ----------------------------------------------------
print("\nrepro.ops registry")
for backend in ops.backends("attention"):
    spec = ops.AttentionSpec(impl=backend.impl, causal=True,
                             block_q=32, block_k=32, block_kv=32)
    out = ops.attention(q, k, v, spec)
    print(f"  attention[{backend.impl:9s}] vs two-pass:",
          f"{float(jnp.max(jnp.abs(out - two_pass))):.2e}")
p_policy = ops.softmax(x, ops.SoftmaxSpec(precision="auto:mrpc"))
print("  named precision policy auto:mrpc ==", FORMAT_MRPC.short_name(),
      "err:", float(jnp.max(jnp.abs(p_policy - p9))))
print("\nOK")
