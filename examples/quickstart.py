"""Quickstart: the STAR softmax engine in three acts.

    PYTHONPATH=src python examples/quickstart.py

1. drop-in quantized softmax (the paper's engine),
2. STAR attention (two-pass and vector-pipelined forms agree),
3. the Pallas kernel matches both.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DEFAULT_FORMAT, FORMAT_MRPC, STAR_SOFTMAX, EXACT_SOFTMAX,
    attention, blocked_attention, exact_softmax, star_softmax,
)
from repro.kernels.flash_star.ops import flash_star_op

rng = np.random.default_rng(0)

# --- 1. the softmax engine ---------------------------------------------------
x = jnp.asarray(rng.normal(size=(4, 128)) * 4, jnp.float32)
p_exact = exact_softmax(x)
p_star = star_softmax(x, DEFAULT_FORMAT, mode="histogram")  # counter+VMM form
print("STAR softmax (8-bit CNEWS format)")
print("  max |p_star - p_exact| =", float(jnp.max(jnp.abs(p_star - p_exact))))
print("  rows sum to", np.asarray(p_star.sum(-1))[:2], "...")
p9 = star_softmax(x, FORMAT_MRPC)
print("  9-bit error:", float(jnp.max(jnp.abs(p9 - p_exact))), "(tighter)")

# --- 2. STAR attention: two-pass vs vector-grained pipeline -------------------
q = jnp.asarray(rng.normal(size=(2, 64, 8, 32)), jnp.float32)
k = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.float32)  # GQA 8:2
v = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), jnp.float32)
two_pass = attention(q, k, v, softmax=STAR_SOFTMAX, causal=True)
pipelined = blocked_attention(q, k, v, softmax=STAR_SOFTMAX, causal=True, block_size=16)
print("\nSTAR attention")
print("  two-pass vs vector-pipeline:", float(jnp.max(jnp.abs(two_pass - pipelined))),
      "(integer-grid arithmetic makes the online form exact)")
exact = attention(q, k, v, softmax=EXACT_SOFTMAX, causal=True)
print("  STAR vs exact attention:   ", float(jnp.max(jnp.abs(two_pass - exact))))

# --- 3. the fused Pallas kernel ----------------------------------------------
kern = flash_star_op(q, k, v, causal=True, block_q=32, block_k=32)
print("\nflash_star Pallas kernel (interpret mode)")
print("  kernel vs two-pass:", float(jnp.max(jnp.abs(kern - two_pass))))
kern8 = flash_star_op(q, k, v, causal=True, pv_int8=True, block_q=32, block_k=32)
print("  int8 P*V variant err:", float(jnp.max(jnp.abs(kern8 - exact))),
      "(beyond-paper: 2x MXU throughput)")
print("\nOK")
