"""Continuous-batching serving demo: staggered requests stream tokens live.

    PYTHONPATH=src python examples/serve_star.py --arch granite_8b

A pool of KV-cache slots absorbs requests as they "arrive" (we submit them
across ticks to mimic network arrival).  Every tick runs one jitted decode
across the whole pool; each slot decodes at its own depth, so short and
long requests coexist without padding or lockstep.  Tokens print as they
are sampled — the streaming view a serving frontend would forward.

Sampling runs through the STAR softmax engine (quantized LUT codebook) when
the config says so; greedy output is bit-identical to one-at-a-time
generation (tests/test_serve.py asserts this).
"""

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.serve.engine import ContinuousBatchingEngine, ContinuousConfig

ATTENTION_ARCHS = [a for a in ARCH_IDS if a not in
                   ("mamba2_130m", "recurrentgemma_2b", "seamless_m4t_large_v2")]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b", choices=ATTENTION_ARCHS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = materialize(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    streams = {}

    def on_token(ev):
        streams.setdefault(ev.uid, []).append(ev.token)
        tail = " <done>" if ev.finished else ""
        print(f"    req{ev.uid} +tok[{ev.index}]={ev.token}{tail}")

    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=args.slots, max_len=64,
                         temperature=args.temperature, star_sampling=True),
        on_token=on_token,
    )

    # Mixed-length requests with staggered arrivals: submit a couple per
    # tick while the engine is already decoding earlier ones.
    pending = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 20))
        gen = int(rng.integers(4, 12))
        kw = {}
        if cfg.family == "vlm":
            kw["patch_embeds"] = rng.standard_normal(
                (1, cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
        pending.append((rng.integers(0, cfg.vocab_size, (plen,)), gen, kw))

    print(f"{args.arch} [{cfg.family}]: {args.requests} requests -> "
          f"{args.slots} slots  (STAR {cfg.softmax_format.short_name()} codebook)")
    tick = 0
    while pending or not eng.scheduler.done():
        if pending and tick % 2 == 0:  # two new arrivals every other tick
            for prompt, gen, kw in pending[:2]:
                uid = eng.submit(prompt, gen, **kw)
                print(f"  [tick {tick}] arrive req{uid} "
                      f"(prompt {len(prompt)} toks, budget {gen})")
            pending = pending[2:]
        eng.step()
        tick += 1

    print(f"\nall {len(streams)} requests served in {eng.ticks} decode ticks:")
    for uid in sorted(streams):
        print(f"  req{uid}: {streams[uid]}")


if __name__ == "__main__":
    main()
