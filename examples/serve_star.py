"""Batched serving with the STAR engine: prefill -> decode -> sampled tokens,
on any of the 10 assigned architectures (reduced configs).

    PYTHONPATH=src python examples/serve_star.py --arch recurrentgemma_2b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.serve.engine import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = materialize(model.param_specs(), jax.random.PRNGKey(0))
    eng = ServeEngine(
        cfg, params,
        ServeConfig(max_len=args.prompt_len + args.gen + cfg.num_patches + 8,
                    temperature=args.temperature, star_sampling=True),
    )
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_patches, cfg.frontend_dim)),
            jnp.float32)
    if cfg.family == "encdec":
        kw["src_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, 48, cfg.frontend_dim)), jnp.float32)

    t0 = time.perf_counter()
    toks, info = eng.generate(prompts, args.gen, key=jax.random.PRNGKey(1), **kw)
    dt = time.perf_counter() - t0
    print(f"{args.arch} [{cfg.family}]: generated {toks.shape[0]}x{toks.shape[1]} "
          f"tokens in {dt:.2f}s  (STAR sampling, "
          f"{cfg.softmax_format.short_name()} codebook)")
    for row in np.asarray(toks):
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
