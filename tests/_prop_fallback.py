"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The property-test modules do::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _prop_fallback import given, settings, st

so tier-1 collection never depends on hypothesis, while the properties are
still *exercised*: ``given`` expands each strategy into a fixed example
sweep — the min/max boundary draw first, then seeded-random draws — and
runs the test body once per example.  No shrinking, no adaptive search;
install hypothesis (``pip install -e .[dev]``) for the real engine.

Only the strategy surface the repo's tests use is implemented:
``st.integers``, ``st.floats``, ``st.lists``.
"""

from __future__ import annotations

import functools
from typing import Callable, List

import numpy as np

N_EXAMPLES = 25  # random draws per property, after the two boundary draws


class _Strategy:
    """A draw function parameterized by mode: 'min' | 'max' | random rng."""

    def __init__(self, draw: Callable):
        self._draw = draw

    def example(self, mode, rng: np.random.Generator):
        return self._draw(mode, rng)


class st:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        def draw(mode, rng):
            if mode == "min":
                return int(min_value)
            if mode == "max":
                return int(max_value)
            return int(rng.integers(min_value, max_value + 1))
        return _Strategy(draw)

    @staticmethod
    def floats(min_value: float, max_value: float, allow_nan: bool = False,
               **_ignored) -> _Strategy:
        def draw(mode, rng):
            if mode == "min":
                return float(min_value)
            if mode == "max":
                return float(max_value)
            return float(rng.uniform(min_value, max_value))
        return _Strategy(draw)

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(mode, rng):
            if mode == "min":
                return [elements.example("min", rng) for _ in range(max(min_size, 1))]
            if mode == "max":
                return [elements.example("max", rng) for _ in range(max_size)]
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(mode, rng) for _ in range(n)]
        return _Strategy(draw)


def settings(**_kwargs):
    """No-op decorator (max_examples/deadline are hypothesis knobs)."""
    def deco(fn):
        return fn
    return deco


def given(**strategies):
    """Run the test over boundary draws + N seeded-random example draws."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            rng = np.random.default_rng(0xC0DEB00C)
            modes: List = ["min", "max"] + ["rand"] * N_EXAMPLES
            for mode in modes:
                kwargs = {name: s.example(mode, rng)
                          for name, s in strategies.items()}
                try:
                    fn(**kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"property falsified by deterministic example {kwargs!r}"
                    ) from e
        # pytest must see a zero-arg signature, not the wrapped one —
        # otherwise the strategy names look like (missing) fixtures
        del wrapper.__wrapped__
        return wrapper
    return deco
