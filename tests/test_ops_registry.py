"""The repro.ops dispatch layer: backend parity, spec hashability,
capability validation, registration, and platform interpret defaults.

The parity suite is parametrized over *whatever the registry holds*: a
newly registered softmax/attention backend is automatically held to the
exact-softmax oracle within its spec's fixed-point tolerance.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.configs import get_smoke_config
from repro.core.fixedpoint import FORMAT_COLA, FORMAT_MRPC
from repro.core.star_softmax import exact_softmax

RNG = np.random.default_rng(11)


def _logits(shape=(6, 96), scale=4.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, jnp.float32)


def _qkv(b=2, tq=17, tk=40, hq=4, hkv=2, d=32):
    q = jnp.asarray(RNG.normal(size=(b, tq, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, tk, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, tk, hkv, d)), jnp.float32)
    return q, k, v


def _supported(impl, **fields):
    """Build a spec for ``impl``, skipping combos its capabilities reject."""
    spec = ops.SoftmaxSpec(impl=impl, **fields)
    try:
        return ops.validate(spec)
    except ops.CapabilityError:
        backend = ops.get("softmax", impl)
        kinds = backend.capabilities.get("kind")
        if kinds and spec.kind not in kinds:
            return ops.validate(dataclasses.replace(spec, kind=kinds[0]))
        raise


SOFTMAX_IMPLS = [b.impl for b in ops.backends("softmax")]
ATTENTION_IMPLS = [b.impl for b in ops.backends("attention")]


# ---------------------------------------------------------------------------
# parity: every registered backend vs the exact_softmax oracle


@pytest.mark.parametrize("impl", SOFTMAX_IMPLS)
@pytest.mark.parametrize(
    "fmt", [None, FORMAT_MRPC, FORMAT_COLA], ids=["default", "mrpc", "cola"]
)
def test_softmax_backend_parity_vs_oracle(impl, fmt):
    x = _logits()
    fields = {} if fmt is None else {"precision": fmt}
    spec = _supported(impl, **fields)
    out = ops.softmax(x, spec)
    err = float(jnp.max(jnp.abs(out - exact_softmax(x))))
    assert err <= spec.tolerance(), (spec, err)
    np.testing.assert_allclose(np.asarray(out.sum(-1)), 1.0, atol=1e-5)


@pytest.mark.parametrize("impl", SOFTMAX_IMPLS)
@pytest.mark.parametrize("mode", ["gather", "onehot", "histogram"])
def test_softmax_backend_modes_agree(impl, mode):
    x = _logits()
    spec = _supported(impl, mode=mode)
    base = _supported(impl)
    np.testing.assert_allclose(
        np.asarray(ops.softmax(x, spec)),
        np.asarray(ops.softmax(x, base)),
        atol=2e-6,
    )


@pytest.mark.parametrize("impl", ATTENTION_IMPLS)
def test_attention_backend_parity_star(impl):
    """Every backend implements the same STAR contract: bit-comparable to
    the reference whole-operand engine (DESIGN.md §2/§3)."""
    q, k, v = _qkv()
    spec = ops.AttentionSpec(
        impl=impl, causal=True, block_q=16, block_k=16, block_kv=16
    )
    ref = ops.attention(q, k, v, spec, impl="reference")
    out = ops.attention(q, k, v, spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


@pytest.mark.parametrize("impl", ATTENTION_IMPLS)
def test_attention_backend_parity_exact_oracle(impl):
    q, k, v = _qkv()
    spec = ops.AttentionSpec(
        impl=impl,
        softmax=ops.SoftmaxSpec(kind="exact"),
        causal=True,
        block_q=16,
        block_k=16,
        block_kv=16,
    )
    ref = ops.attention(q, k, v, spec, impl="reference")
    out = ops.attention(q, k, v, spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


def test_matmul_hwmodel_tracks_xla():
    x = jnp.asarray(RNG.normal(size=(32, 128)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(128, 64)) * 0.05, jnp.float32)
    exact = ops.matmul(x, w)
    hw = ops.matmul(x, w, impl="hwmodel")
    rel = float(jnp.max(jnp.abs(hw - exact)) / jnp.max(jnp.abs(exact)))
    assert rel < 0.15, rel  # 8-bit operands + 5-bit ADC quantization


def test_ssd_scan_backends_agree():
    xdt = jnp.asarray(RNG.normal(size=(1, 64, 4, 16)), jnp.float32)
    a = -jnp.abs(jnp.asarray(RNG.normal(size=(1, 64, 4)) * 0.1, jnp.float32))
    bm = jnp.asarray(RNG.normal(size=(1, 64, 16)) * 0.3, jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(1, 64, 16)) * 0.3, jnp.float32)
    y_p, h_p = ops.ssd_scan(xdt, a, bm, cm, chunk=16)
    y_r, h_r = ops.ssd_scan(xdt, a, bm, cm, impl="reference", chunk=16)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_r), atol=1e-5)


# ---------------------------------------------------------------------------
# specs: hashable, frozen, jit-cache-stable


def test_specs_hashable_and_value_equal():
    a = ops.AttentionSpec(causal=True, softmax=ops.SoftmaxSpec(precision="auto:mrpc"))
    b = ops.AttentionSpec(causal=True, softmax=ops.SoftmaxSpec(precision="auto:mrpc"))
    assert a == b and hash(a) == hash(b)
    assert len({a, b}) == 1
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.causal = False


def test_spec_as_jit_cache_key_no_retrace():
    import functools

    traces = []

    @functools.partial(jax.jit, static_argnames=("spec",))
    def g(x, spec):
        traces.append(spec)
        return ops.softmax(x, spec)

    x = _logits()
    g(x, spec=ops.SoftmaxSpec())
    g(x + 1, spec=ops.SoftmaxSpec())  # equal spec -> cached, no retrace
    assert len(traces) == 1
    g(x, spec=ops.SoftmaxSpec(precision="auto:mrpc"))  # new spec -> one more
    assert len(traces) == 2


def test_named_precision_policy_resolves():
    assert ops.SoftmaxSpec(precision="auto:mrpc").fmt == FORMAT_MRPC
    assert ops.SoftmaxSpec(kind="exact").fmt is None
    with pytest.raises(ValueError, match="auto:<dataset>"):
        ops.SoftmaxSpec(precision="mrpc")


def test_spec_json_roundtrips():
    import json

    spec = ops.validate(ops.AttentionSpec(impl="pallas", causal=True))
    blob = json.dumps(ops.spec_json(spec))
    assert json.loads(blob)["softmax"]["kind"] == "star"


# ---------------------------------------------------------------------------
# capability validation + registration + use()


def test_capability_mismatch_is_actionable():
    with pytest.raises(ops.CapabilityError) as ei:
        ops.softmax(_logits(), impl="xla", kind="star")
    msg = str(ei.value)
    assert "xla" in msg and "kind" in msg and "reference" in msg  # the fix is named


def test_unknown_backend_lists_registered():
    with pytest.raises(ops.UnknownBackendError, match="pallas"):
        ops.softmax(_logits(), impl="definitely-not-registered")


def test_attention_pv_int8_capability():
    q, k, v = _qkv()
    with pytest.raises(ops.CapabilityError, match="pallas"):
        ops.attention(q, k, v, impl="reference", pv_int8=True)


def test_register_and_use_override():
    def zeros_backend(spec, x, *, where=None, axis=-1):
        return jnp.zeros_like(x)

    ops.register("softmax", "test-zeros", zeros_backend, description="test stub")
    try:
        x = _logits()
        # explicit impl routes to the new backend
        assert float(jnp.max(ops.softmax(x, impl="test-zeros"))) == 0.0
        # use() retargets dispatches that asked for another impl
        with ops.use(softmax="test-zeros"):
            assert float(jnp.max(ops.softmax(x, impl="reference"))) == 0.0
        # and the override frame pops
        assert float(jnp.max(ops.softmax(x, impl="reference"))) > 0.0
    finally:
        ops.unregister("softmax", "test-zeros")
    with pytest.raises(ops.UnknownBackendError):
        ops.softmax(x, impl="test-zeros")


def test_use_rejects_unknown_keys():
    with pytest.raises(ops.OpDispatchError, match="valid keys"):
        with ops.use(softmaxx="reference"):
            pass


def test_duplicate_registration_requires_overwrite():
    def stub(spec, x, *, where=None, axis=-1):
        return x

    ops.register("softmax", "test-dup", stub)
    try:
        with pytest.raises(ops.OpDispatchError, match="overwrite"):
            ops.register("softmax", "test-dup", stub)
        ops.register("softmax", "test-dup", stub, overwrite=True)
    finally:
        ops.unregister("softmax", "test-dup")


# ---------------------------------------------------------------------------
# platform + config integration


def test_default_interpret_matches_platform(monkeypatch):
    assert ops.default_interpret() == (ops.detected_platform() != "tpu")
    monkeypatch.setenv("REPRO_OPS_INTERPRET", "0")
    assert ops.default_interpret() is False
    monkeypatch.setenv("REPRO_OPS_INTERPRET", "1")
    assert ops.default_interpret() is True


def test_resolved_spec_has_concrete_interpret():
    spec = ops.validate(ops.SoftmaxSpec(impl="pallas"))
    assert spec.interpret in (True, False)


def test_config_carries_specs():
    cfg = get_smoke_config("granite_8b")
    spec = cfg.attention_spec
    assert spec.impl == "xla" and spec.block_kv == 32
    assert cfg.softmax_spec.kind == "star"
    # the test idiom: legacy loose-field replace still wins over the spec
    exact = dataclasses.replace(cfg, softmax_kind="exact")
    assert exact.softmax_spec.kind == "exact"
    assert exact.attention_spec.softmax.kind == "exact"


def test_config_validates_through_registry():
    cfg = get_smoke_config("granite_8b")
    ops.validate(cfg.attention_spec)
    ops.validate(cfg.softmax_spec)


def test_config_legacy_block_size_replace_wins():
    cfg = get_smoke_config("granite_8b")  # carries block_kv=32 in its spec
    bumped = dataclasses.replace(cfg, attn_block_size=64)
    spec = bumped.attention_spec
    assert spec.block_kv == 64 and spec.block_q == 64 and spec.block_k == 64


def test_moe_router_exact_falls_back_from_star_only_impl():
    # a star-only softmax impl + an exact-kind override must not raise at
    # the MoE router (layers.moe reroutes the oracle through reference)
    from repro.models.layers import moe, spec_moe
    from repro.models.param import materialize

    cfg = dataclasses.replace(
        get_smoke_config("granite_moe_1b_a400m"),
        softmax=ops.SoftmaxSpec(impl="pallas", kind="star"),
        softmax_kind="exact",  # the legacy-replace idiom
    )
    params = materialize(spec_moe(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    out = moe(params, x, cfg)
    assert out.shape == x.shape and bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# paged attention (block-pool KV decode — DESIGN.md §8)

PAGED_IMPLS = [b.impl for b in ops.backends("paged_attention")]


def _paged_operands(s=3, w=3, bs=4, hq=4, hkv=2, d=16):
    n = s * w + 1  # block 0 reserved as scratch
    q = jnp.asarray(RNG.normal(size=(s, 1, hq, d)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(n, bs, hkv, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(n, bs, hkv, d)), jnp.float32)
    tables = jnp.asarray(
        [[i * w + j + 1 for j in range(w)] for i in range(s)], jnp.int32
    )
    kvl = jnp.asarray([6, 11, 2], jnp.int32)
    return q, kp, vp, tables, kvl


def test_paged_attention_registered_backends():
    assert {"reference", "xla", "pallas"} <= set(PAGED_IMPLS)
    assert ops.get("attention", "paged") is not None  # the layout marker


@pytest.mark.parametrize("impl", PAGED_IMPLS)
def test_paged_attention_backend_parity(impl):
    q, kp, vp, tables, kvl = _paged_operands()
    spec = ops.PagedAttentionSpec(impl=impl, block_size=4)
    ref = ops.paged_attention(
        q, kp, vp, tables, spec, kv_valid_len=kvl, kv_len=10, impl="reference"
    )
    out = ops.paged_attention(q, kp, vp, tables, spec, kv_valid_len=kvl, kv_len=10)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


@pytest.mark.parametrize("impl", PAGED_IMPLS)
def test_paged_attention_matches_dense_gather(impl):
    """Gathering a block table reproduces the dense cache: the paged op
    must agree with dense attention over the manually flattened blocks."""
    q, kp, vp, tables, kvl = _paged_operands()
    s, w = tables.shape
    bs = kp.shape[1]
    flat = np.asarray(tables).reshape(-1)
    kd = jnp.asarray(np.asarray(kp)[flat].reshape(s, w * bs, *kp.shape[2:])[:, :10])
    vd = jnp.asarray(np.asarray(vp)[flat].reshape(s, w * bs, *vp.shape[2:])[:, :10])
    dense = ops.attention(
        q,
        kd,
        vd,
        ops.AttentionSpec(impl="reference", causal=False),
        kv_valid_len=kvl,
    )
    out = ops.paged_attention(
        q,
        kp,
        vp,
        tables,
        ops.PagedAttentionSpec(impl=impl, block_size=4),
        kv_valid_len=kvl,
        kv_len=10,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), atol=3e-6)


def test_paged_attention_use_override():
    q, kp, vp, tables, kvl = _paged_operands()
    ref = ops.paged_attention(
        q, kp, vp, tables, kv_valid_len=kvl, kv_len=10, impl="reference"
    )
    with ops.use(paged_attention="reference"):
        out = ops.paged_attention(
            q,
            kp,
            vp,
            tables,
            ops.PagedAttentionSpec(impl="xla"),
            kv_valid_len=kvl,
            kv_len=10,
        )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_paged_attention_pallas_capability():
    q, kp, vp, tables, kvl = _paged_operands()
    spec = ops.PagedAttentionSpec(
        impl="pallas", softmax=ops.SoftmaxSpec(kind="star_ste")
    )
    with pytest.raises(ops.CapabilityError, match="pallas"):
        ops.paged_attention(q, kp, vp, tables, spec, kv_valid_len=kvl)


def test_paged_spec_validation_and_json():
    import json

    with pytest.raises(ValueError, match="block_size"):
        ops.PagedAttentionSpec(block_size=0)
    spec = ops.validate(ops.PagedAttentionSpec(impl="pallas"))
    assert spec.interpret in (True, False)
    blob = json.dumps(ops.spec_json(spec))
    assert json.loads(blob)["op"] == "paged_attention"


def test_config_derives_paged_spec():
    cfg = get_smoke_config("granite_8b")
    spec = cfg.paged_attention_spec
    assert spec.impl == "xla"
    assert spec.softmax == cfg.softmax_spec
    # the "paged" marker impl maps to xla math for the inner op
    paged_cfg = dataclasses.replace(cfg, attn_impl="paged")
    assert paged_cfg.attention_spec.impl == "paged"
    assert paged_cfg.paged_attention_spec.impl == "xla"
    ops.validate(paged_cfg.attention_spec)  # the marker impl is registered
