"""Kernel sweep: flash_star fused attention (interpret) vs oracles."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.core.fixedpoint import DEFAULT_FORMAT, FORMAT_COLA
from repro.kernels.flash_star.ref import flash_star_blocked_ref, flash_star_ref

RNG = np.random.default_rng(11)


def flash_star_op(q, k, v, *, fmt=DEFAULT_FORMAT, causal=True,
                  sliding_window=None, q_offset=0, kv_valid_len=None,
                  pv_int8=False, block_q=128, block_k=128):
    """Dispatch-layer call the retired ``ops.py`` shim used to wrap
    (``fmt=None`` selects the exact-softmax kind)."""
    softmax = (
        ops.SoftmaxSpec(kind="exact") if fmt is None
        else ops.SoftmaxSpec(kind="star", precision=fmt)
    )
    spec = ops.AttentionSpec(
        impl="pallas", softmax=softmax, causal=causal,
        sliding_window=sliding_window, block_q=block_q, block_k=block_k,
        pv_int8=pv_int8,
    )
    return ops.attention(q, k, v, spec, q_offset=q_offset,
                         kv_valid_len=kv_valid_len)


def qkv(b, tq, tk, hq, hkv, d, dtype=jnp.float32):
    return (
        jnp.asarray(RNG.normal(size=(b, tq, hq, d)), dtype),
        jnp.asarray(RNG.normal(size=(b, tk, hkv, d)), dtype),
        jnp.asarray(RNG.normal(size=(b, tk, hkv, d)), dtype),
    )


CASES = [
    dict(b=2, tq=64, tk=64, hq=4, hkv=4, d=32, causal=True, fmt=DEFAULT_FORMAT),
    dict(b=1, tq=33, tk=70, hq=8, hkv=2, d=16, causal=True, fmt=DEFAULT_FORMAT),
    dict(b=2, tq=50, tk=50, hq=2, hkv=1, d=64, causal=False, fmt=FORMAT_COLA),
    dict(b=1, tq=96, tk=96, hq=2, hkv=2, d=32, causal=True, fmt=None),  # exact
    dict(b=2, tq=1, tk=80, hq=4, hkv=2, d=32, causal=True, fmt=DEFAULT_FORMAT),  # decode
]


@pytest.mark.parametrize("case", CASES, ids=lambda c: f"t{c['tq']}x{c['tk']}h{c['hq']}kv{c['hkv']}{'c' if c['causal'] else ''}{'x' if c['fmt'] is None else ''}")
def test_kernel_vs_two_pass_ref(case):
    q, k, v = qkv(case["b"], case["tq"], case["tk"], case["hq"], case["hkv"], case["d"])
    off = case["tk"] - case["tq"] if case["causal"] else 0
    kvl = jnp.full((case["b"],), case["tk"], jnp.int32).at[0].set(max(1, case["tk"] - 7))
    out = flash_star_op(q, k, v, fmt=case["fmt"], causal=case["causal"],
                        q_offset=off, kv_valid_len=kvl, block_q=32, block_k=32)
    ref = flash_star_ref(q, k, v, fmt=case["fmt"], causal=case["causal"],
                         q_offset=off, kv_valid_len=kvl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-6)


def test_kernel_vs_blocked_ref():
    q, k, v = qkv(2, 64, 64, 4, 2, 32)
    out = flash_star_op(q, k, v, causal=True, block_q=16, block_k=16)
    ref = flash_star_blocked_ref(q, k, v, causal=True, block_size=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-6)


def test_sliding_window():
    q, k, v = qkv(2, 64, 64, 4, 2, 32)
    out = flash_star_op(q, k, v, causal=True, sliding_window=24, block_q=16, block_k=16)
    ref = flash_star_ref(q, k, v, causal=True, sliding_window=24)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=str)
def test_dtypes(dtype):
    q, k, v = qkv(1, 32, 32, 2, 2, 32, dtype)
    out = flash_star_op(q, k, v, causal=True, block_q=16, block_k=16)
    ref = flash_star_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=2e-2
    )


def test_pv_int8_close_to_f32():
    """Beyond-paper int8 P.V path: error bounded by the int8 mantissa grid."""
    q, k, v = qkv(2, 64, 64, 4, 2, 32)
    out8 = flash_star_op(q, k, v, causal=True, pv_int8=True, block_q=32, block_k=32)
    ref = flash_star_ref(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(out8 - ref))) < 0.05


def test_block_size_invariance():
    q, k, v = qkv(1, 48, 48, 2, 2, 16)
    outs = [
        np.asarray(flash_star_op(q, k, v, causal=True, block_q=bq, block_k=bk))
        for bq, bk in [(16, 16), (48, 16), (16, 48), (48, 48)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=5e-6)
