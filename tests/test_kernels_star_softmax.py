"""Kernel sweep: star_softmax Pallas (interpret) vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.core.fixedpoint import FORMAT_CNEWS, FORMAT_COLA, FORMAT_MRPC
from repro.kernels.star_softmax.kernel import star_softmax_pallas
from repro.kernels.star_softmax.ref import exact_softmax_ref, star_softmax_ref

RNG = np.random.default_rng(7)


def star_softmax_op(x, fmt, *, block_rows=8, mode="gather"):
    """Dispatch-layer call the retired ``ops.py`` shim used to wrap."""
    return ops.softmax(x, ops.SoftmaxSpec(
        impl="pallas", kind="star", mode=mode, precision=fmt,
        block_rows=block_rows,
    ))

SHAPES = [(3, 128), (5, 7, 33), (2, 4, 257), (1, 512), (16, 64)]
FMTS = [FORMAT_CNEWS, FORMAT_MRPC, FORMAT_COLA]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.short_name())
def test_kernel_matches_ref(shape, fmt):
    x = jnp.asarray(RNG.normal(size=shape) * 5, jnp.float32)
    ref = star_softmax_ref(x, fmt)
    out = star_softmax_op(x, fmt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_kernel_dtypes(dtype):
    x = jnp.asarray(RNG.normal(size=(8, 96)) * 5, dtype)
    ref = star_softmax_ref(x, FORMAT_CNEWS)
    out = star_softmax_op(x, FORMAT_CNEWS)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-3)


@pytest.mark.parametrize("kw", [
    {"mode": "histogram"},
    {"mode": "onehot"},
    {"block_rows": 4},
    {"block_rows": 16},
])
def test_kernel_variants(kw):
    x = jnp.asarray(RNG.normal(size=(13, 130)) * 5, jnp.float32)
    ref = star_softmax_ref(x, FORMAT_CNEWS)
    out = star_softmax_op(x, FORMAT_CNEWS, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_kernel_legacy_combined_dataflow():
    """The one-hot MXU numerator + histogram denominator combination has no
    spec mode (the registry's modes are exclusive); it stays reachable by
    calling the kernel directly."""
    x = jnp.asarray(RNG.normal(size=(13, 130)) * 5, jnp.float32)
    ref = star_softmax_ref(x, FORMAT_CNEWS)
    out = star_softmax_pallas(
        x, fmt=FORMAT_CNEWS, block_rows=8, use_histogram=True,
        use_mxu_lut=True, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_kernel_error_vs_exact_within_bound():
    x = jnp.asarray(RNG.normal(size=(32, 256)) * 5, jnp.float32)
    out = star_softmax_op(x, FORMAT_CNEWS)
    exact = exact_softmax_ref(x)
    assert float(jnp.max(jnp.abs(out - exact))) < np.exp(FORMAT_CNEWS.resolution) - 1
