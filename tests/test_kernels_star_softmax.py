"""Kernel sweep: star_softmax Pallas (interpret) vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fixedpoint import FORMAT_CNEWS, FORMAT_COLA, FORMAT_MRPC
from repro.kernels.star_softmax.ops import star_softmax_op
from repro.kernels.star_softmax.ref import exact_softmax_ref, star_softmax_ref

RNG = np.random.default_rng(7)

SHAPES = [(3, 128), (5, 7, 33), (2, 4, 257), (1, 512), (16, 64)]
FMTS = [FORMAT_CNEWS, FORMAT_MRPC, FORMAT_COLA]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.short_name())
def test_kernel_matches_ref(shape, fmt):
    x = jnp.asarray(RNG.normal(size=shape) * 5, jnp.float32)
    ref = star_softmax_ref(x, fmt)
    out = star_softmax_op(x, fmt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_kernel_dtypes(dtype):
    x = jnp.asarray(RNG.normal(size=(8, 96)) * 5, dtype)
    ref = star_softmax_ref(x, FORMAT_CNEWS)
    out = star_softmax_op(x, FORMAT_CNEWS)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-3)


@pytest.mark.parametrize("kw", [
    {"use_histogram": True},
    {"use_mxu_lut": True},
    {"use_histogram": True, "use_mxu_lut": True},
    {"block_rows": 4},
    {"block_rows": 16},
])
def test_kernel_variants(kw):
    x = jnp.asarray(RNG.normal(size=(13, 130)) * 5, jnp.float32)
    ref = star_softmax_ref(x, FORMAT_CNEWS)
    out = star_softmax_op(x, FORMAT_CNEWS, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_kernel_error_vs_exact_within_bound():
    x = jnp.asarray(RNG.normal(size=(32, 256)) * 5, jnp.float32)
    out = star_softmax_op(x, FORMAT_CNEWS)
    exact = exact_softmax_ref(x)
    assert float(jnp.max(jnp.abs(out - exact))) < np.exp(FORMAT_CNEWS.resolution) - 1
