import os
import sys

# Tests run on the single real CPU device.  The 512-device flag is ONLY for
# launch/dryrun.py (its own subprocess) — never set it here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
