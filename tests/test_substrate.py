"""Data pipeline, optimizer, checkpoint, schedules, sharding rules."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.configs import get_smoke_config
from repro.data.synthetic import DataConfig, batch_iterator, make_batch
from repro.optim.adamw import (
    AdamWConfig, adamw_update, clip_by_global_norm, global_norm, init_opt_state,
)
from repro.optim.schedule import cosine_with_warmup


# ----------------------------- data -----------------------------------------


def test_data_deterministic_and_shifted():
    cfg = get_smoke_config("granite_8b")
    b1 = make_batch(cfg, batch=4, seq_len=32, step=7)
    b2 = make_batch(cfg, batch=4, seq_len=32, step=7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shift
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # different steps/shards differ
    b3 = make_batch(cfg, batch=4, seq_len=32, step=8)
    b4 = make_batch(cfg, batch=4, seq_len=32, step=7, shard=1)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert not np.array_equal(b1["tokens"], b4["tokens"])


def test_data_learnable_structure():
    """The Markov stream must be more predictable than uniform."""
    cfg = get_smoke_config("granite_8b")
    b = make_batch(cfg, batch=8, seq_len=256, step=0, data_cfg=DataConfig(noise=0.05))
    t = np.asarray(b["tokens"])
    # order-2 Markov determinism: the same (prev2, prev1) context almost
    # always yields the same next token (up to the 5% noise hops)
    ctx = {}
    total = hits = 0
    for row in t:
        for i in range(2, len(row)):
            key = (row[i - 2], row[i - 1])
            if key in ctx:
                total += 1
                hits += ctx[key] == row[i]
            else:
                ctx[key] = row[i]
    assert total > 100 and hits / total > 0.75, (hits, total)


def test_iterator_families():
    for arch in ("qwen2_vl_7b", "seamless_m4t_large_v2"):
        cfg = get_smoke_config(arch)
        it = batch_iterator(cfg, batch=2, seq_len=16)
        b = next(it)
        if cfg.family == "vlm":
            assert "patch_embeds" in b
        if cfg.family == "encdec":
            assert "src_embeds" in b


# ----------------------------- optimizer ------------------------------------


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt_state(params)
    target = jnp.asarray([1.0, 2.0])
    cfg = AdamWConfig(weight_decay=0.0)
    for i in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, opt = adamw_update(
            g, opt, params, lr=jnp.asarray(0.1), cfg=cfg, step=jnp.asarray(i + 1)
        )
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_weight_decay_skips_vectors():
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    opt = init_opt_state(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new, _ = adamw_update(zero_g, opt, params, lr=jnp.asarray(0.1),
                          cfg=AdamWConfig(weight_decay=0.1), step=jnp.asarray(1))
    assert float(jnp.max(jnp.abs(new["b"] - 1.0))) < 1e-6  # no decay on bias
    assert float(jnp.max(new["w"])) < 1.0  # decay on matrix


def test_schedule_shape():
    s = jnp.asarray([0, 50, 100, 5000, 10000])
    lr = cosine_with_warmup(s, peak_lr=1e-3, warmup=100, total=10000)
    assert float(lr[0]) == 0.0
    assert float(lr[2]) == pytest.approx(1e-3)
    assert float(lr[4]) < float(lr[2])


# ----------------------------- checkpoint -----------------------------------


def test_checkpoint_roundtrip_and_rotation():
    state = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "step": jnp.asarray(3, jnp.int32),
    }
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3):
            checkpointer.save(d, s, state)
        checkpointer.rotate(d, keep=2)
        assert checkpointer.latest_step(d) == 3
        assert len([x for x in os.listdir(d) if x.startswith("step_")]) == 2
        template = jax.tree.map(np.zeros_like, state)
        restored, step = checkpointer.restore(d, template)
        assert step == 3
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
        )


def test_checkpoint_atomic_no_partial():
    """A .tmp dir (crashed writer) is never picked up as latest."""
    state = {"w": jnp.ones(3)}
    with tempfile.TemporaryDirectory() as d:
        checkpointer.save(d, 1, state)
        os.makedirs(os.path.join(d, "step_00000002.tmp"))
        assert checkpointer.latest_step(d) == 1


# ----------------------------- sharding rules --------------------------------


def test_logical_rules_divisibility_and_single_use():
    from jax.sharding import PartitionSpec as P
    import numpy as np
    from jax.sharding import Mesh
    from repro.distributed.sharding import DEFAULT_RULES, logical_to_pspec

    del Mesh, np  # Mesh with repeated device objects is invalid; build an
    # abstract mesh instead.  The AbstractMesh constructor changed across
    # jax versions: <= 0.4.x takes one (name, size) pair tuple, newer
    # takes (shape, axis_names).
    from jax.sharding import AbstractMesh

    try:
        mesh = AbstractMesh((4, 4), ("data", "model"))
    except TypeError:  # jax <= 0.4.x signature
        mesh = AbstractMesh((("data", 4), ("model", 4)))
    # divisible: shard
    assert logical_to_pspec(("vocab",), (512,), DEFAULT_RULES, mesh) == P("model")
    # not divisible: auto-drop
    assert logical_to_pspec(("vocab",), (510,), DEFAULT_RULES, mesh) == P(None)
    # single-use: expert takes model first, mlp drops it
    spec = logical_to_pspec(("expert", "embed", "mlp"), (8, 64, 64), DEFAULT_RULES, mesh)
    assert spec == P("model", "data", None)
