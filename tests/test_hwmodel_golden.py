"""Golden-value regression locks for the analytical hardware model.

``tests/test_hwmodel.py`` checks the paper's *bands* (0.06x area ±0.03 …),
which is the right acceptance test but leaves a wide corridor where a
silent constant or formula regression can drift undetected — the
``lut_crossbar`` read-power audit (power divided by ``CAM_SEARCH_TIME``
instead of ``XBAR_READ_TIME``, a ~5x overstatement of LUT power) sat
inside the band.  This suite pins the post-audit model outputs to
committed constants at float precision, so any change to the cost
formulas or device constants shows up as an explicit golden update in
review, not as an invisible walk across the band.

The goldens were recomputed from the model after the audit fix; they are
derived values, so updating a device constant legitimately moves them —
re-derive with::

    PYTHONPATH=src python -c "from repro.hwmodel.star_engine import \
        table1, fig3; print(table1()); print(fig3())"
"""

import pytest

from repro.hwmodel import constants as C
from repro.hwmodel.crossbar import cam_crossbar, lut_crossbar, vmm_crossbar
from repro.hwmodel.star_engine import fig3, table1

REL = 1e-9  # float-precision lock: these are deterministic host floats

# -- committed goldens (post lut_crossbar power audit) ----------------------

TABLE1_GOLDEN = {
    "ours_area": 0.0585392,
    "ours_power": 0.045178181818181814,
    "ours_area_mm2": 0.00585392,
    "ours_power_w": 0.0074544,
    "vs_softermax_area": 0.17739151515151513,
    "vs_softermax_power": 0.3764848484848484,
}

FIG3_GOLDEN = {
    "star_model": 610.9387112542746,
    "retransformer_model": 498.2364840941855,
    "star_vs_retransformer_model": 1.2262022769468326,
}


def test_table1_golden_values():
    t = table1()
    assert t["ours_model"]["area"] == pytest.approx(
        TABLE1_GOLDEN["ours_area"], rel=REL
    )
    assert t["ours_model"]["power"] == pytest.approx(
        TABLE1_GOLDEN["ours_power"], rel=REL
    )
    assert t["ours_abs"]["area_mm2"] == pytest.approx(
        TABLE1_GOLDEN["ours_area_mm2"], rel=REL
    )
    assert t["ours_abs"]["power_w"] == pytest.approx(
        TABLE1_GOLDEN["ours_power_w"], rel=REL
    )
    assert t["vs_softermax_model"]["area"] == pytest.approx(
        TABLE1_GOLDEN["vs_softermax_area"], rel=REL
    )
    assert t["vs_softermax_model"]["power"] == pytest.approx(
        TABLE1_GOLDEN["vs_softermax_power"], rel=REL
    )


def test_fig3_golden_values():
    f = fig3()
    for key, want in FIG3_GOLDEN.items():
        assert f[key] == pytest.approx(want, rel=REL), key


# -- the audited formulas themselves ----------------------------------------


def test_lut_power_uses_read_time_denominator():
    """The audit fix: a LUT access is a row READ (cell settle + sense at
    ``XBAR_READ_TIME``), not a match-line search — dividing the per-read
    energy by ``CAM_SEARCH_TIME`` overstated the read-power term 50x
    (~5x on the total once periphery power is added)."""
    rows, cols = 512, 16
    lut = lut_crossbar(rows, cols)
    e_read = cols * C.XBAR_READ_ENERGY_PER_CELL
    assert lut.power_w == pytest.approx(
        e_read / C.XBAR_READ_TIME + C.PERIPH_POWER_PER_XBAR, rel=REL
    )
    buggy = e_read / C.CAM_SEARCH_TIME + C.PERIPH_POWER_PER_XBAR
    assert lut.power_w < buggy / 2  # far from the pre-audit value
    # issue cadence stays at the search rate (banked rows pipeline)
    assert lut.op_time_s == C.CAM_SEARCH_TIME


def test_cam_power_uses_search_time_denominator():
    rows, cols = 512, 16
    cam = cam_crossbar(rows, cols)
    e_search = rows * C.CAM_SEARCH_ENERGY_PER_ROW
    assert cam.power_w == pytest.approx(
        e_search / C.CAM_SEARCH_TIME + C.PERIPH_POWER_PER_XBAR, rel=REL
    )
    assert cam.op_time_s == C.CAM_SEARCH_TIME


def test_vmm_power_formula():
    rows, cols, n_adc = 128, 128, 4
    vmm = vmm_crossbar(rows, cols, n_adc)
    e_read = rows * cols * C.XBAR_READ_ENERGY_PER_CELL
    assert vmm.power_w == pytest.approx(
        e_read / C.XBAR_READ_TIME + n_adc * C.ADC5_POWER
        + C.PERIPH_POWER_PER_XBAR,
        rel=REL,
    )
    assert vmm.op_time_s == C.XBAR_READ_TIME
