"""Launch-layer units that do NOT need 512 devices: input specs for all
cells, the HLO collective parser, roofline math."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, all_cells, get_config, shapes_for
from repro.launch.roofline import (
    collective_bytes,
    model_flops,
    roofline_terms,
)
from repro.launch.specs import input_specs


def test_cell_enumeration():
    cells = all_cells()
    assert len(cells) == 33  # 10 x 3 + 3 long_500k
    assert ("mamba2_130m", "long_500k") in cells
    assert ("llama3_405b", "long_500k") not in cells  # full-attention skip
    assert ("mixtral_8x22b", "long_500k") in cells  # SWA caps the cache


@pytest.mark.parametrize("cell", all_cells(), ids=lambda c: f"{c[0]}-{c[1]}")
def test_input_specs_structure(cell):
    arch, shape_name = cell
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    kind, inputs = input_specs(cfg, shape)
    leaves = jax.tree.leaves(inputs)
    assert all(isinstance(x, jax.ShapeDtypeStruct) or isinstance(x, int) for x in leaves)
    if kind == "train":
        toks = inputs["batch"]["tokens"]
        assert toks.shape[0] == shape.global_batch
    elif kind == "decode":
        assert inputs["tokens"].shape == (shape.global_batch, 1)
        # the cache really is seq_len deep (or window/state capped)
        cache_leaves = jax.tree.leaves(inputs["cache"])
        assert len(cache_leaves) >= 2


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,1024]{1,0} all-gather(%p0), replica_groups={}, dimensions={1}
  %ar = f32[128,1024]{1,0} all-reduce(%ag), to_apply=%add
  %rs.1 = bf16[64,512]{1,0} reduce-scatter(%ar), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %a2a = s8[32,32]{1,0} all-to-all(%p0), dimensions={0}
"""
    res = collective_bytes(hlo)
    by = res["by_op"]
    assert by["all-gather"] == 128 * 256 * 4
    assert by["all-reduce"] == 128 * 1024 * 4
    assert by["reduce-scatter"] == 128 * 1024 * 4  # operand %ar
    assert by["collective-permute"] == 128 * 256 * 4
    assert by["all-to-all"] == 128 * 256 * 4
    assert res["count"]["all-gather"] == 1


def test_collective_parser_skips_done_ops():
    hlo = """
  %p0 = f32[16,16]{1,0} parameter(0)
  %ags = (f32[16,16], f32[64,16]) all-gather-start(%p0), dimensions={0}
  %agd = f32[64,16]{1,0} all-gather-done(%ags)
"""
    res = collective_bytes(hlo)
    assert res["count"].get("all-gather", 0) == 1  # start counted, done not


def test_roofline_terms_math():
    t = roofline_terms(
        flops_per_dev=197e12, bytes_per_dev=819e9, coll_bytes_per_dev=0.0
    )
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(1.0)
    assert t["dominant"] in ("compute", "memory")
    t2 = roofline_terms(flops_per_dev=1e12, bytes_per_dev=1e9, coll_bytes_per_dev=1e12)
    assert t2["dominant"] == "collective"


def test_model_flops_train_vs_infer():
    assert model_flops(1e9, 0, 1000, "train") == 6e12
    assert model_flops(1e9, 5e8, 1000, "prefill") == 2 * 5e8 * 1000


def test_production_mesh_requires_devices():
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(RuntimeError):
        make_production_mesh()  # only 1 device in the test process
