"""Gather-free paged-attention decode kernel (DESIGN.md §11).

Three claims pinned here:

1. **Parity** — ``("paged_attention", "pallas_paged")`` matches the gather
   reference backend within spec tolerance across ragged lengths, block
   sizes {8, 16}, STAR and exact softmax, ring (sliding-window) clamping,
   GQA ratios, and through the serve engine (greedy token parity incl.
   M-RoPE and ring-wrap archs).
2. **Gather-freedom** — the kernel's jaxpr contains no gathered
   ``[S, W*bs, Hkv, D]`` operand at any point, while every gather adapter
   provably materializes one (the structural form of the perf claim; the
   counted-traffic form lives in ``ops.paged_gather_bytes``).
3. **Capability envelope** — like the other fused kernels, pallas_paged
   declares no per-cell fault path and no ``star_ste`` kind; dispatch must
   refuse, not silently degrade.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.configs import get_smoke_config
from repro.kernels.paged_attention import paged_flash_attention
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.serve.engine import (
    ContinuousBatchingEngine,
    ContinuousConfig,
    ServeConfig,
    ServeEngine,
)

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(7)
MAX_LEN = 40


def _operands(s=3, w=4, bs=8, hq=4, hkv=2, d=16, lens=(6, 25, 0)):
    n = s * w + 1  # block 0 reserved as scratch
    q = jnp.asarray(RNG.normal(size=(s, 1, hq, d)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(n, bs, hkv, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(n, bs, hkv, d)), jnp.float32)
    # shuffled non-contiguous tables: the kernel must follow the table,
    # not the pool order
    perm = RNG.permutation(np.arange(1, n))
    tables = jnp.asarray(perm[: s * w].reshape(s, w), jnp.int32)
    kvl = jnp.asarray(lens, jnp.int32)
    return q, kp, vp, tables, kvl


def _spec(impl, kind, bs):
    return ops.PagedAttentionSpec(
        impl=impl, block_size=bs, softmax=ops.SoftmaxSpec(kind=kind)
    )


# ---------------------------------------------------------------------------
# op-level parity vs the gather reference oracle


@pytest.mark.parametrize("bs", [8, 16])
@pytest.mark.parametrize("kind", ["star", "exact"])
def test_parity_ragged_vs_gather_reference(bs, kind):
    q, kp, vp, tables, kvl = _operands(bs=bs, lens=(6, 25, 2))
    ref = ops.paged_attention(
        q, kp, vp, tables, _spec("reference", kind, bs), kv_valid_len=kvl
    )
    out = ops.paged_attention(
        q, kp, vp, tables, _spec("pallas_paged", kind, bs), kv_valid_len=kvl
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


@pytest.mark.parametrize("kind", ["star", "exact"])
def test_empty_slot_emits_zeros(kind):
    """valid == 0 (a free serve slot) emits exactly zeros, never NaN —
    the fused-kernel contract (flash_star does the same; the *reference*
    exact path instead averages the masked garbage window, which is why
    the parity sweep never includes a zero-length slot)."""
    q, kp, vp, tables, kvl = _operands(lens=(6, 25, 0))
    out = ops.paged_attention(
        q, kp, vp, tables, _spec("pallas_paged", kind, 8), kv_valid_len=kvl
    )
    assert np.all(np.asarray(out)[2] == 0.0)
    assert np.all(np.isfinite(np.asarray(out)))


@pytest.mark.parametrize("lens", [(1, 8, 9), (32, 17, 24)])
def test_parity_block_boundary_lengths(lens):
    """Valid lengths on and just past block edges (the mask/clamp seams)."""
    q, kp, vp, tables, kvl = _operands(bs=8, lens=lens)
    ref = ops.paged_attention(
        q, kp, vp, tables, _spec("reference", "star", 8), kv_valid_len=kvl
    )
    out = ops.paged_attention(
        q, kp, vp, tables, _spec("pallas_paged", "star", 8), kv_valid_len=kvl
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


def test_parity_ring_clamp_kv_len():
    """Ring caches pass kv_len = cache_t < table capacity: the kernel must
    clamp the ragged lengths exactly like the gather path crops rows."""
    q, kp, vp, tables, kvl = _operands(bs=8, w=4, lens=(30, 32, 12))
    ref = ops.paged_attention(
        q, kp, vp, tables, _spec("reference", "star", 8),
        kv_valid_len=kvl, kv_len=16,
    )
    out = ops.paged_attention(
        q, kp, vp, tables, _spec("pallas_paged", "star", 8),
        kv_valid_len=kvl, kv_len=16,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
def test_parity_gqa_ratios(hq, hkv):
    q, kp, vp, tables, kvl = _operands(hq=hq, hkv=hkv, lens=(6, 25, 11))
    ref = ops.paged_attention(
        q, kp, vp, tables, _spec("reference", "exact", 8), kv_valid_len=kvl
    )
    out = ops.paged_attention(
        q, kp, vp, tables, _spec("pallas_paged", "exact", 8), kv_valid_len=kvl
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


def test_kernel_rejects_bad_gqa_and_multitoken_queries():
    q, kp, vp, tables, kvl = _operands()
    with pytest.raises(AssertionError, match="GQA"):
        paged_flash_attention(
            q[:, 0, :3], kp, vp, tables, kvl, fmt=None, interpret=True
        )
    q2 = jnp.concatenate([q, q], axis=1)  # Tq = 2
    with pytest.raises(ops.CapabilityError, match="decode kernel"):
        ops.paged_attention(
            q2, kp, vp, tables, _spec("pallas_paged", "star", 8),
            kv_valid_len=kvl,
        )


# ---------------------------------------------------------------------------
# gather-freedom: the structural no-[S, W*bs, H, D] assertion


def _jaxpr_avals(jaxpr, acc):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            acc.append(v.aval)
        for val in eqn.params.values():
            if isinstance(val, jax.core.ClosedJaxpr):
                _jaxpr_avals(val.jaxpr, acc)
            elif isinstance(val, jax.core.Jaxpr):
                _jaxpr_avals(val, acc)
            elif isinstance(val, (tuple, list)):
                for item in val:
                    if isinstance(item, jax.core.ClosedJaxpr):
                        _jaxpr_avals(item.jaxpr, acc)
                    elif isinstance(item, jax.core.Jaxpr):
                        _jaxpr_avals(item, acc)
    return acc


def _materializes_gathered_operand(impl) -> bool:
    q, kp, vp, tables, kvl = _operands()
    s, w = tables.shape
    _, bs, hkv, d = kp.shape
    spec = _spec(impl, "star", bs)

    def call(q, kp, vp, tables, kvl):
        return ops.paged_attention(q, kp, vp, tables, spec, kv_valid_len=kvl)

    avals = _jaxpr_avals(jax.make_jaxpr(call)(q, kp, vp, tables, kvl), [])
    gathered = (s, w * bs, hkv, d)
    return any(getattr(a, "shape", None) == gathered for a in avals)


def test_pallas_paged_never_materializes_the_gathered_window():
    assert not _materializes_gathered_operand("pallas_paged")


@pytest.mark.parametrize("impl", ["reference", "xla"])
def test_gather_adapters_do_materialize_it(impl):
    """The control: the assertion above is meaningful because the same
    probe finds the dense [S, W*bs, Hkv, D] operand in every gather
    adapter's program."""
    assert _materializes_gathered_operand(impl)


def test_counted_gather_bytes_model():
    common = dict(table_width=8, block_size=16, num_kv_heads=2, head_dim=64)
    xla = ops.paged_gather_bytes("xla", live_lens=[8, 24, 0], **common)
    pp = ops.paged_gather_bytes("pallas_paged", live_lens=[8, 24, 0], **common)
    row = 2 * 2 * 64 * 4  # K+V rows, f32
    assert xla == 3 * 8 * 16 * row  # full table window, occupancy-blind
    # live pages only; the empty slot still touches its one clamped page
    assert pp == (16 + 32 + 16) * row
    assert xla / pp >= 1.5  # the BENCH_paged_decode acceptance shape


# ---------------------------------------------------------------------------
# capability envelope


def test_fault_capability_refused():
    q, kp, vp, tables, kvl = _operands()
    fault = ops.FaultModel(stuck_on_rate=0.01, seed=0)
    spec = ops.PagedAttentionSpec(
        impl="pallas_paged", softmax=ops.SoftmaxSpec(kind="star", fault=fault)
    )
    with pytest.raises(ops.CapabilityError, match="pallas_paged"):
        ops.paged_attention(q, kp, vp, tables, spec, kv_valid_len=kvl)


def test_star_ste_kind_refused():
    q, kp, vp, tables, kvl = _operands()
    spec = ops.PagedAttentionSpec(
        impl="pallas_paged", softmax=ops.SoftmaxSpec(kind="star_ste")
    )
    with pytest.raises(ops.CapabilityError, match="pallas_paged"):
        ops.paged_attention(q, kp, vp, tables, spec, kv_valid_len=kvl)


# ---------------------------------------------------------------------------
# serve-engine token parity through the gather-free kernel


def _model_params(arch="granite_8b"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    return cfg, materialize(model.param_specs(), KEY)


def _expected(cfg, params, prompts, gens, frontends=None):
    ref = ServeEngine(cfg, params, ServeConfig(max_len=MAX_LEN, temperature=0.0))
    fes = frontends or [{} for _ in prompts]
    return [
        np.asarray(ref.generate(
            jnp.asarray(p)[None], g,
            **{k: jnp.asarray(v) for k, v in fe.items()})[0])[0].tolist()
        for p, g, fe in zip(prompts, gens, fes)
    ]


@pytest.mark.parametrize("arch,lens", [
    ("granite_8b", (5, 11, 8, 3)),       # dense append path
    ("mixtral_8x22b", (20, 11, 18, 3)),  # window=16 ring: prompts wrap
])
def test_engine_greedy_parity_pallas_paged(arch, lens):
    cfg, params = _model_params(arch)
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in lens]
    gens = [4, 2, 5, 3]
    expected = _expected(cfg, params, prompts, gens)
    with ops.use(paged_attention="pallas_paged"):
        eng = ContinuousBatchingEngine(
            cfg, params,
            ContinuousConfig(num_slots=2, max_len=MAX_LEN,
                             kv_layout="paged", kv_block_size=4))
        uids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
        done = eng.run()
    assert [done[u] for u in uids] == expected
    # the engine accounted gather-free traffic for the resolved impl
    assert eng.kv_stats()["gather_bytes_per_token"] > 0


def test_engine_vlm_mrope_parity_pallas_paged():
    cfg, params = _model_params("qwen2_vl_7b")
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 9)]
    pe = [RNG.standard_normal((1, cfg.num_patches, cfg.frontend_dim))
          .astype(np.float32) for _ in prompts]
    gens = [3, 2]
    expected = _expected(cfg, params, prompts, gens,
                         [{"patch_embeds": e} for e in pe])
    with ops.use(paged_attention="pallas_paged"):
        eng = ContinuousBatchingEngine(
            cfg, params,
            ContinuousConfig(num_slots=2, max_len=MAX_LEN,
                             kv_layout="paged", kv_block_size=4))
        uids = [eng.submit(p, g, patch_embeds=e)
                for p, g, e in zip(prompts, gens, pe)]
        done = eng.run()
    assert [done[u] for u in uids] == expected


def test_config_pallas_attn_maps_to_pallas_paged():
    import dataclasses

    cfg = get_smoke_config("granite_8b")
    spec = dataclasses.replace(cfg, attn_impl="pallas").paged_attention_spec
    assert spec.impl == "pallas_paged"
    ops.validate(spec)
