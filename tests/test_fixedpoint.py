import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic example sweep instead
    from _prop_fallback import given, settings, st

from repro.core.fixedpoint import (
    DEFAULT_FORMAT,
    FORMAT_CNEWS,
    FORMAT_COLA,
    FORMAT_MRPC,
    GRID_SENTINEL,
    FixedPointFormat,
    dequantize,
    grid_index,
    quantize_index,
    quantize_logits,
    quantize_value,
    quantize_value_ste,
)


def test_paper_formats():
    assert FORMAT_CNEWS.total_bits == 8 and FORMAT_CNEWS.frac_bits == 2
    assert FORMAT_MRPC.total_bits == 9 and FORMAT_MRPC.frac_bits == 3
    assert FORMAT_COLA.total_bits == 7 and FORMAT_COLA.frac_bits == 2
    assert DEFAULT_FORMAT == FORMAT_CNEWS


def test_format_properties():
    f = FixedPointFormat(6, 2)
    assert f.num_levels == 256
    assert f.scale == 4.0
    assert f.min_value == -255 / 4
    assert f.resolution == 0.25
    assert "8" in f.short_name() or "6i.2f" in f.short_name()


def test_format_validation():
    with pytest.raises(ValueError):
        FixedPointFormat(-1, 2)
    with pytest.raises(ValueError):
        FixedPointFormat(0, 0)
    with pytest.raises(ValueError):
        FixedPointFormat(12, 12)


def test_quantize_index_basics():
    f = FixedPointFormat(6, 2)
    z = jnp.asarray([0.0, -0.25, -0.26, -63.75, -1000.0, 0.5])
    k = quantize_index(z, f)
    assert k.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(k), [0, 1, 1, 255, 255, 0])


def test_quantize_nan_maps_to_deepest():
    f = DEFAULT_FORMAT
    k = quantize_index(jnp.asarray([jnp.nan]), f)
    assert int(k[0]) == f.num_levels - 1
    j = quantize_logits(jnp.asarray([jnp.nan]), f)
    assert int(j[0]) == GRID_SENTINEL


def test_roundtrip_error_bound():
    f = FixedPointFormat(6, 3)
    rng = np.random.default_rng(0)
    z = -np.abs(rng.normal(size=1000) * 10)
    zq = np.asarray(quantize_value(jnp.asarray(z), f))
    in_range = z >= f.min_value
    assert np.max(np.abs(zq[in_range] - z[in_range])) <= f.resolution / 2 + 1e-6


def test_grid_index_matches_subtraction():
    f = DEFAULT_FORMAT
    rng = np.random.default_rng(1)
    x = rng.normal(size=256) * 6
    j = quantize_logits(jnp.asarray(x), f)
    m = jnp.max(j)
    k = grid_index(j, m, f)
    assert int(jnp.min(k)) == 0  # the max element matches level 0
    assert k.shape == x.shape


def test_ste_gradient():
    f = DEFAULT_FORMAT
    g = jax.grad(lambda z: jnp.sum(quantize_value_ste(z, f)))(
        jnp.asarray([-1.0, -100.0, 0.5])
    )
    np.testing.assert_array_equal(np.asarray(g), [1.0, 0.0, 0.0])


@settings(max_examples=50, deadline=None)
@given(
    ib=st.integers(min_value=1, max_value=8),
    fb=st.integers(min_value=0, max_value=4),
    vals=st.lists(st.floats(min_value=-60, max_value=0, allow_nan=False), min_size=1, max_size=32),
)
def test_property_quantize_monotone(ib, fb, vals):
    """Quantization preserves order: z1 <= z2 => k1 >= k2 (index counts depth)."""
    f = FixedPointFormat(ib, fb)
    z = jnp.asarray(sorted(vals), jnp.float32)
    k = np.asarray(quantize_index(z, f), np.int32)
    assert np.all(np.diff(k) <= 0)


@settings(max_examples=30, deadline=None)
@given(
    fb=st.integers(min_value=0, max_value=4),
    v=st.floats(min_value=-50, max_value=0, allow_nan=False),
)
def test_property_roundtrip_idempotent(fb, v):
    f = FixedPointFormat(6, fb)
    z = jnp.asarray([v], jnp.float32)
    once = quantize_value(z, f)
    twice = quantize_value(once, f)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice))
