"""The §Perf hillclimb levers must preserve numerics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, run_train
from repro.train.step import TrainConfig

RNG = np.random.default_rng(7)
KEY = jax.random.PRNGKey(1)


def test_onehot_kv_update_matches_dus():
    cfg0 = dataclasses.replace(get_smoke_config("granite_8b"), softmax_kind="exact")
    cfg1 = dataclasses.replace(cfg0, kv_update="onehot")
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = materialize(m0.param_specs(), KEY)
    toks = jnp.asarray(RNG.integers(0, 256, (2, 30)), jnp.int32)
    _, c0 = m0.prefill(params, toks[:, :24], max_len=30)
    _, c1 = m1.prefill(params, toks[:, :24], max_len=30)
    for i in range(6):
        s0, c0 = m0.decode_step(params, c0, toks[:, 24 + i:25 + i])
        s1, c1 = m1.decode_step(params, c1, toks[:, 24 + i:25 + i])
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-5)


def test_bf16_moments_close_to_fp32():
    cfg = get_smoke_config("granite_8b")
    lc = LoopConfig(num_steps=10, batch=4, seq_len=32, log_every=100)
    r32 = run_train(cfg, TrainConfig(adamw=AdamWConfig(moments_dtype="float32")),
                    lc, log_fn=lambda *_: None)
    r16 = run_train(cfg, TrainConfig(adamw=AdamWConfig(moments_dtype="bfloat16")),
                    lc, log_fn=lambda *_: None)
    l32 = r32["history"][-1]["loss"]
    l16 = r16["history"][-1]["loss"]
    assert l16 == pytest.approx(l32, rel=0.03), (l32, l16)
    # and the moments really are half-size
    mu = r16["state"]["opt"]["mu"]
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(mu))


def test_seq_parallel_activations_numerics():
    """SP carry constraint is a no-op numerically (single device)."""
    cfg0 = get_smoke_config("granite_8b")
    cfg1 = dataclasses.replace(cfg0, seq_parallel_activations=True)
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = materialize(m0.param_specs(), KEY)
    toks = jnp.asarray(RNG.integers(0, 256, (2, 32)), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(m0.forward(params, toks)),
        np.asarray(m1.forward(params, toks)),
        atol=1e-6,
    )
