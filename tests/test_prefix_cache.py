"""Shared-prefix KV cache + chunked prefill (DESIGN.md §12).

Three layers under test:

* ``PrefixCache`` — the radix trie over block-size token chunks: lookup
  caps (one suffix token always prefills), full-block-only inserts, pins
  that outlive the donor request, LRU leaf eviction gated on refcount.
* ``BlockPool.ensure_writable(block_index=...)`` — the any-index
  copy-on-write fix: a sliding-window ring wraps in place and writes
  blocks *other than the last*, so privatizing only the tail corrupts a
  fork sibling's KV (the regression reproduced here at the device level).
* The continuous engine with ``prefix_cache`` / ``prefill_chunk_tokens``
  — greedy output must be token-identical to the uncached monolithic
  path on every arch family, with ``tokens_saved`` > 0 on shared-prefix
  traffic and trie eviction (not deadlock) under pool pressure.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.serve.engine import ContinuousBatchingEngine, ContinuousConfig
from repro.serve.paged import BlockPool, PrefixCache

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)
MAX_LEN = 40


def _model_params(arch="granite_8b"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    return cfg, materialize(model.param_specs(), KEY)


# ---------------------------------------------------------------------------
# PrefixCache trie (host allocator state only)


def test_lookup_on_empty_trie_is_a_miss():
    trie = PrefixCache(BlockPool(9, 4))
    blocks, rows = trie.lookup(list(range(12)))
    assert blocks == [] and rows == 0
    assert trie.hits == 0 and trie.tokens_saved == 0


def test_lookup_never_covers_the_whole_prompt():
    """At least one suffix token must prefill so admission has logits to
    sample from: an exact-multiple prompt matches one chunk short."""
    pool = BlockPool(9, 4)
    trie = PrefixCache(pool)
    toks = list(range(8))
    table = pool.allocate(0, 2)
    assert trie.insert(toks, table) == 2
    blocks, rows = trie.lookup(toks)  # same 8 tokens: only block 0 usable
    assert blocks == table[:1] and rows == 4
    blocks, rows = trie.lookup(toks + [99])  # 1 extra token: both blocks
    assert blocks == table and rows == 8
    assert trie.hits == 2 and trie.tokens_saved == 4 + 8


def test_insert_indexes_full_blocks_only():
    pool = BlockPool(9, 4)
    trie = PrefixCache(pool)
    table = pool.allocate(0, 3)  # 10 rows: the tail block is half-full
    assert trie.insert(list(range(10)), table) == 2
    assert len(trie) == 2
    # re-inserting the same prefix adds nothing (first writer wins)
    assert trie.insert(list(range(10)), table) == 0


def test_shared_prefix_branches_share_trie_nodes():
    pool = BlockPool(9, 4)
    trie = PrefixCache(pool)
    a = pool.allocate(0, 2)
    b = pool.allocate(1, 2)
    trie.insert([1, 2, 3, 4, 5, 6, 7, 8], a)
    # same first chunk, different second chunk: only one new node
    assert trie.insert([1, 2, 3, 4, 9, 9, 9, 9], b) == 1
    assert len(trie) == 3
    # the shared first chunk kept the first writer's block
    blocks, _ = trie.lookup([1, 2, 3, 4, 9, 9, 9, 9, 0])
    assert blocks == [a[0], b[1]]


def test_trie_pins_survive_donor_release():
    """The whole point of pinning: cached KV outlives the request that
    prefilled it, and a later admission adopts the same blocks."""
    pool = BlockPool(9, 4)
    trie = PrefixCache(pool)
    toks = list(range(8))
    table = pool.allocate(0, 2)
    trie.insert(toks, table)
    freed = pool.release(0)
    assert freed == []  # pins keep every block allocated
    assert all(pool.refcount(b) == 1 for b in table)
    blocks, rows = trie.lookup(toks + [5])
    assert blocks == table and rows == 8
    adopted = pool.adopt(7, blocks)
    assert adopted == table
    assert all(pool.refcount(b) == 2 for b in table)


def test_eviction_is_lru_leaf_only_and_respects_live_tables():
    pool = BlockPool(9, 4)
    trie = PrefixCache(pool)
    a = pool.allocate(0, 2)
    b = pool.allocate(1, 1)
    trie.insert([1, 2, 3, 4, 5, 6, 7, 8], a)  # chain of 2 nodes
    trie.insert([9, 9, 9, 9], b)              # separate branch
    pool.release(0)
    pool.release(1)
    trie.lookup([9, 9, 9, 9, 0])  # touch branch b: branch a is now LRU
    assert trie.evict_one()
    # the LRU *leaf* went first: a's tail node, never the interior node
    assert pool.refcount(a[1]) == 0 and pool.refcount(a[0]) == 1
    # a block adopted by a live table is not evictable
    pool.adopt(5, [a[0]])
    pool.adopt(6, [b[0]])
    trie.lookup([1, 2, 3, 4, 0])  # a's head is LRU... but both are shared
    assert not trie.evict_one()
    pool.release(5)
    assert trie.evict_one()  # a's head is reclaimable again
    assert trie.evicted == 2


def test_clear_returns_pool_to_pristine():
    pool = BlockPool(9, 4)
    trie = PrefixCache(pool)
    for uid in range(3):
        toks = RNG.integers(0, 50, (8,)).tolist()
        trie.insert(toks, pool.allocate(uid, 2))
        pool.release(uid)
    nodes = len(trie)
    assert nodes > 0 and pool.used_blocks > 0
    assert trie.clear() == nodes
    assert len(trie) == 0 and trie.clear() == 0
    assert pool.free_blocks == pool.usable_blocks
    assert not pool._refcount


# ---------------------------------------------------------------------------
# ensure_writable(block_index=...) — the ring-wrap CoW fix


def test_ensure_writable_privatizes_the_indexed_block():
    pool = BlockPool(9, 4)
    table = pool.allocate(0, 3)
    pool.fork(0, 1)
    # a ring wrap writes block 0, not the tail: index 0 must privatize
    src, dst = pool.ensure_writable(1, block_index=0)
    assert src == table[0] and pool.table(1)[0] == dst
    assert pool.table(1)[1:] == table[1:]  # untouched entries still shared
    assert pool.refcount(src) == 1 and pool.refcount(dst) == 1
    assert pool.ensure_writable(1, block_index=0) is None  # now exclusive
    # the default (no index) remains the append-only tail behavior
    src2, _ = pool.ensure_writable(1)
    assert src2 == table[-1]


def _cow_decode(model, params, host, dev, tables, uids, tokens, cache_t):
    """One lockstep paged decode tick under the fork CoW protocol: each
    slot privatizes the block its wrapped write lands in before the
    device step (exactly what an engine must do for forked tables)."""
    bs = host.block_size
    for s, uid in enumerate(uids):
        row = int(dev["pos"][s]) % cache_t
        cow = host.ensure_writable(uid, block_index=row // bs)
        if cow is not None:
            src, dst = cow
            dev = model.copy_block(dev, src, dst)
            tables[s][row // bs] = dst
    logits, dev = model.decode_step_paged(
        params, dev, tokens, jnp.asarray(tables, jnp.int32), cache_t=cache_t)
    return logits, dev


def test_ring_fork_sibling_survives_wrap():
    """Regression for the last-block-only CoW assumption: fork a
    sliding-window request, decode both branches past the ring wrap, and
    check the sibling's logits against a run where it owned private
    blocks from the start.  Privatizing only the tail block (the old
    behavior) corrupts the sibling the moment the wrapped write lands in
    a still-shared block — reproduced below as the negative control."""
    cfg, params = _model_params("mixtral_8x22b")  # sliding_window = 16
    model = build_model(cfg)
    bs, steps = 4, 6
    cache_t = model.cache_len(MAX_LEN)  # == window: writes wrap at row 0
    width = cache_t // bs
    prompt = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, cache_t)), jnp.int32)
    _, cache = model.prefill(params, prompt, MAX_LEN)
    feeds = RNG.integers(0, cfg.vocab_size, (steps, 2, 1)).astype(np.int32)

    def run(forked, cow_index):
        host = BlockPool(2 * width + 2, bs)
        ta = host.allocate(0, width)
        dev = model.init_paged_cache(2 * width + 2, bs, num_slots=2)
        dev = model.write_slot_paged(dev, cache, 0, jnp.asarray(ta, jnp.int32))
        if forked:
            tb = host.fork(0, 1)
            dev = {**dev, "len": dev["len"].at[1].set(dev["len"][0]),
                   "pos": dev["pos"].at[1].set(dev["pos"][0])}
        else:
            tb = host.allocate(1, width)
            dev = model.write_slot_paged(dev, cache, 1, jnp.asarray(tb, jnp.int32))
        tables = [list(ta), list(tb)]
        out = []
        for i in range(steps):
            for s, uid in enumerate((0, 1)):
                row = int(dev["pos"][s]) % cache_t
                idx = row // bs if cow_index else None
                cowed = host.ensure_writable(uid, block_index=idx)
                if cowed is not None:
                    dev = model.copy_block(dev, *cowed)
                    tables[s][row // bs if cow_index else -1] = cowed[1]
            lg, dev = model.decode_step_paged(
                params, dev, jnp.asarray(feeds[i]),
                jnp.asarray(tables, jnp.int32), cache_t=cache_t)
            out.append(np.asarray(lg))
        return np.stack(out)

    truth = run(forked=False, cow_index=True)
    fixed = run(forked=True, cow_index=True)
    np.testing.assert_array_equal(fixed, truth)
    # negative control: tail-only privatization corrupts a sibling once
    # the wrapped write lands in a shared non-tail block
    buggy = run(forked=True, cow_index=False)
    assert not np.array_equal(buggy, truth)


# ---------------------------------------------------------------------------
# engine: chunked prefill + prefix sharing, token parity with the
# uncached monolithic path


def _run_engine(cfg, params, reqs, **kw):
    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN, **kw))
    uids = [eng.submit(p, g, **fe) for p, g, fe in reqs]
    done = eng.run(max_ticks=500)
    return [done[u] for u in uids], eng


def _shared_prefix_reqs(cfg, n=5, prefix_len=12):
    rng = np.random.default_rng(7)
    pre = rng.integers(0, cfg.vocab_size, (prefix_len,))
    return [
        (np.concatenate([pre, rng.integers(0, cfg.vocab_size,
                                           (int(rng.integers(2, 7)),))]),
         int(rng.integers(3, 7)), {})
        for _ in range(n)
    ]


def test_prefix_cache_parity_and_tokens_saved():
    cfg, params = _model_params()
    reqs = _shared_prefix_reqs(cfg)
    base, _ = _run_engine(cfg, params, reqs,
                          kv_layout="paged", kv_block_size=4)
    out, eng = _run_engine(cfg, params, reqs,
                           kv_layout="paged", kv_block_size=4,
                           prefix_cache=True, prefill_chunk_tokens=6)
    assert out == base
    st = eng.kv_stats()["prefix"]
    assert st["hits"] > 0 and st["tokens_saved"] > 0
    assert eng.metrics.counter("kv.prefix.tokens_saved").value() == \
        st["tokens_saved"]
    # everything drains: live tables gone, only trie pins hold blocks
    assert eng.block_pool.used_blocks == len(eng.prefix)


def test_prefix_cache_parity_under_eviction_pressure():
    """A pool too small to keep trie + live tables resident forces LRU
    trie eviction (and possibly preemption) — output stays identical."""
    cfg, params = _model_params()
    reqs = _shared_prefix_reqs(cfg, n=7)
    base, _ = _run_engine(cfg, params, reqs,
                          kv_layout="paged", kv_block_size=4)
    out, eng = _run_engine(cfg, params, reqs,
                           kv_layout="paged", kv_block_size=4,
                           kv_pool_blocks=9,
                           prefix_cache=True, prefill_chunk_tokens=6)
    assert out == base
    st = eng.kv_stats()["prefix"]
    assert st["evicted"] > 0
    assert st["tokens_saved"] > 0


def test_chunked_prefill_parity_ring_and_dense():
    """Chunked prefill alone (no sharing) must be token-identical on the
    ring arch (linear staging + finalize) and the dense layout."""
    cfg, params = _model_params("mixtral_8x22b")
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, cfg.vocab_size, (n,)), g, {})
            for n, g in ((20, 4), (7, 5), (18, 3))]
    base, _ = _run_engine(cfg, params, reqs,
                          kv_layout="paged", kv_block_size=4)
    out, eng = _run_engine(cfg, params, reqs,
                           kv_layout="paged", kv_block_size=4,
                           prefill_chunk_tokens=8)
    assert out == base
    dense, _ = _run_engine(cfg, params, reqs, prefill_chunk_tokens=8)
    basedense, _ = _run_engine(cfg, params, reqs)
    assert dense == basedense


def test_chunked_prefill_parity_vlm_mrope():
    cfg, params = _model_params("qwen2_vl_7b")
    rng = np.random.default_rng(5)
    reqs = []
    for n, g in ((5, 3), (9, 2)):
        pe = rng.standard_normal(
            (1, cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
        reqs.append((rng.integers(0, cfg.vocab_size, (n,)), g,
                     {"patch_embeds": pe}))
    base, _ = _run_engine(cfg, params, reqs,
                          kv_layout="paged", kv_block_size=4)
    out, eng = _run_engine(cfg, params, reqs,
                           kv_layout="paged", kv_block_size=4,
                           prefix_cache=True, prefill_chunk_tokens=6)
    assert out == base
    # frontend requests never share through the trie (patch rows are not
    # keyed by token ids), but the engine still serves them chunked
    assert eng.kv_stats()["prefix"]["hits"] == 0


def test_prefix_cache_opt_outs_and_validation():
    cfg, params = _model_params("mixtral_8x22b")
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=2, max_len=MAX_LEN, kv_layout="paged",
                         kv_block_size=4, prefix_cache=True))
    assert eng.prefix is None  # rings opt out: the window loses the prefix
    cfg_m, params_m = _model_params("granite_moe_1b_a400m")
    eng_m = ContinuousBatchingEngine(
        cfg_m, params_m,
        ContinuousConfig(num_slots=2, max_len=MAX_LEN, kv_layout="paged",
                         kv_block_size=4, prefix_cache=True))
    assert eng_m.prefix is None  # MoE KV depends on sequence-global state
    cfg_d, params_d = _model_params()
    with pytest.raises(ValueError, match="prefix_cache"):
        ContinuousBatchingEngine(
            cfg_d, params_d,
            ContinuousConfig(num_slots=2, max_len=MAX_LEN,
                             prefix_cache=True))
    with pytest.raises(ValueError, match="prefill_chunk_tokens"):
        ContinuousBatchingEngine(
            cfg_d, params_d,
            ContinuousConfig(num_slots=2, max_len=MAX_LEN,
                             prefill_chunk_tokens=0))


def test_kv_stats_prefix_field_shape():
    cfg, params = _model_params()
    _, eng = _run_engine(cfg, params, _shared_prefix_reqs(cfg, n=2),
                         kv_layout="paged", kv_block_size=4,
                         prefix_cache=True)
    st = eng.kv_stats()["prefix"]
    assert set(st) == {"hits", "tokens_saved", "evicted", "nodes"}
    _, eng2 = _run_engine(cfg, params, _shared_prefix_reqs(cfg, n=2),
                          kv_layout="paged", kv_block_size=4)
    assert eng2.kv_stats()["prefix"] is None
