"""Per-arch reduced-config smoke tests: forward + one train step on CPU,
shape + finiteness assertions (assignment requirement)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.param import count_params, materialize
from repro.models.registry import build_model
from repro.train.state import init_state
from repro.train.step import TrainConfig, make_train_step

RNG = np.random.default_rng(3)
KEY = jax.random.PRNGKey(0)


def make_inputs(cfg, b=2, t=32):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, t)), jnp.int32),
        "labels": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, t)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(b, cfg.num_patches, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            RNG.normal(size=(b, 24, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = materialize(model.param_specs(), KEY)
    batch = make_inputs(cfg)
    if cfg.family == "encdec":
        logits = model.forward(params, batch)
    else:
        logits = model.forward(params, batch["tokens"],
                               **({"patch_embeds": batch["patch_embeds"]} if cfg.family == "vlm" else {}))
    b, t = batch["tokens"].shape
    expect_t = t + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, expect_t, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    state = init_state(model.param_specs(), KEY)
    step = jax.jit(make_train_step(model, TrainConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)))
    batch = make_inputs(cfg)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]) and float(metrics["loss"]) > 0
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, pq: acc or bool(jnp.any(pq)),
        jax.tree.map(lambda a, b: jnp.any(a != b), state["params"], new_state["params"]),
        False,
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "granite_8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen2_72b": (80, 8192, 64, 8, 29568, 152064),
        "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
        "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "mamba2_130m": (24, 768, 24, 24, 0, 50280),
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256206),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (got, expected)
    # MoE / SSM / hybrid extras
    if arch == "granite_moe_1b_a400m":
        assert (cfg.num_experts, cfg.top_k) == (32, 8)
    if arch == "mixtral_8x22b":
        assert (cfg.num_experts, cfg.top_k) == (8, 2) and cfg.sliding_window
    if arch == "mamba2_130m":
        assert cfg.ssm_state == 128
    if arch == "recurrentgemma_2b":
        assert cfg.block_pattern == ("recurrent", "recurrent", "attention")
    if arch == "seamless_m4t_large_v2":
        assert cfg.num_decoder_layers == 24


def test_param_counts_plausible():
    """Full-config parameter counts land near the advertised sizes."""
    expect = {
        "granite_8b": (7e9, 10e9),
        "qwen2_72b": (65e9, 80e9),
        "deepseek_coder_33b": (30e9, 37e9),
        "llama3_405b": (380e9, 430e9),
        "mamba2_130m": (0.10e9, 0.20e9),
        "mixtral_8x22b": (130e9, 150e9),
        "recurrentgemma_2b": (2.0e9, 3.8e9),  # full-matrix LRU gates (no block-diag)
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = count_params(build_model(cfg).param_specs())
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_smoke_config_same_family():
    for arch in ARCH_IDS:
        assert get_smoke_config(arch).family == get_config(arch).family
