import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic example sweep instead
    from _prop_fallback import given, settings, st

from repro.core.fixedpoint import DEFAULT_FORMAT, FixedPointFormat
from repro.core.star_softmax import (
    exact_softmax,
    quantization_error,
    star_softmax,
    star_softmax_ste,
)

RNG = np.random.default_rng(0)


def logits(shape, scale=4.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, jnp.float32)


@pytest.mark.parametrize("mode", ["gather", "onehot", "histogram"])
def test_modes_agree(mode):
    x = logits((4, 16, 64))
    base = star_softmax(x, DEFAULT_FORMAT, mode="gather")
    out = star_softmax(x, DEFAULT_FORMAT, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=1e-6)


def test_rows_sum_to_one():
    x = logits((8, 128))
    for mode in ("gather", "onehot", "histogram"):
        p = star_softmax(x, DEFAULT_FORMAT, mode=mode)
        np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, atol=1e-5)


def test_error_vs_exact_bounded():
    x = logits((16, 256))
    err = float(jnp.max(jnp.abs(star_softmax(x, DEFAULT_FORMAT) - exact_softmax(x))))
    # theoretical bound for grid resolution r: |p_hat - p| <~ e^r - 1
    r = DEFAULT_FORMAT.resolution
    assert err < np.exp(r) - 1 + 1e-3


def test_more_bits_less_error():
    x = logits((32, 128))
    errs = []
    for fb in (0, 1, 2, 3, 4):
        fmt = FixedPointFormat(6, fb)
        errs.append(float(jnp.max(quantization_error(x, fmt))))
    assert errs == sorted(errs, reverse=True) or errs[0] > errs[-1]


def test_masking():
    x = logits((4, 32))
    mask = jnp.asarray(RNG.random((4, 32)) > 0.4)
    for mode in ("gather", "histogram"):
        p = star_softmax(x, DEFAULT_FORMAT, mode=mode, where=mask)
        assert bool(jnp.all(jnp.where(mask, True, p == 0)))
        np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, atol=1e-5)


def test_fully_masked_row_is_zero():
    x = logits((2, 8))
    mask = jnp.zeros((2, 8), bool)
    p = star_softmax(x, DEFAULT_FORMAT, where=mask)
    np.testing.assert_array_equal(np.asarray(p), 0.0)


def test_axis_argument():
    x = logits((3, 16, 5))
    p = star_softmax(x, DEFAULT_FORMAT, axis=1)
    np.testing.assert_allclose(np.asarray(p.sum(1)), 1.0, atol=1e-5)


def test_ste_backward_matches_exact_softmax_vjp():
    x = logits((4, 32))
    g_out = logits((4, 32), 1.0)
    p = star_softmax(x, DEFAULT_FORMAT)
    _, vjp = jax.vjp(lambda z: star_softmax_ste(z, DEFAULT_FORMAT, -1, "gather"), x)
    (gx,) = vjp(g_out)
    expected = p * (g_out - jnp.sum(g_out * p, -1, keepdims=True))
    np.testing.assert_allclose(np.asarray(gx), np.asarray(expected), atol=1e-5)


def test_nan_robustness():
    x = logits((2, 16)).at[0, 3].set(jnp.nan)
    p = star_softmax(x, DEFAULT_FORMAT)
    assert bool(jnp.all(jnp.isfinite(p)))


# ---------------- property tests (paper invariants) -------------------------


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_shift_invariance_on_grid(n, seed):
    """STAR softmax is exactly invariant to shifts that land on the grid
    (integer-grid arithmetic) — the paper's x - x_max normalization."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n) * 5, jnp.float32)
    shift = 8.25  # multiple of resolution 0.25
    a = star_softmax(x, DEFAULT_FORMAT)
    b = star_softmax(x + shift, DEFAULT_FORMAT)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_permutation_equivariance(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n) * 5, jnp.float32)
    perm = rng.permutation(n)
    a = np.asarray(star_softmax(x, DEFAULT_FORMAT))[perm]
    b = np.asarray(star_softmax(x[perm], DEFAULT_FORMAT))
    np.testing.assert_allclose(a, b, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_property_codebook_closure(seed):
    """Every output probability is lut[k] / denominator for some level k —
    numerators live in the finite codebook (the paper's LUT claim)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=32) * 4, jnp.float32)
    fmt = DEFAULT_FORMAT
    p = np.asarray(star_softmax(x, fmt), np.float64)
    lut = np.exp(-np.arange(fmt.num_levels) / fmt.scale)
    den = p.sum() and (1.0 / p[p > 0].min())  # reconstruct scale-free check
    # each positive prob ratio p_i / p_max must equal lut[k] for some k
    ratios = p[p > 0] / p.max()
    dist = np.min(np.abs(ratios[:, None] - lut[None, :]), axis=1)
    assert np.max(dist) < 1e-5
