"""Unit tests for the observability subsystem (repro.obs, DESIGN.md §10):
tracer ring buffer + Chrome export, metrics primitives, and the shared
benchmark timing helpers.  Pure host-side — no model, (almost) no jax."""

import json

import pytest

from repro import obs
from repro.obs.metrics import _lkey


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(autouse=True)
def _clean_globals():
    obs.reset()
    yield
    obs.reset()


# ---------------------------------------------------------------------------
# Tracer


def test_span_records_complete_event_with_fake_clock():
    clk = FakeClock()
    tr = obs.Tracer(clock=clk)
    clk.advance(1.0)
    with tr.span("work", uid=7):
        clk.advance(0.25)
    (ev,) = tr.events
    assert (ev.name, ev.ph) == ("work", "X")
    assert ev.ts == pytest.approx(1.0e6)
    assert ev.dur == pytest.approx(0.25e6)
    assert ev.args == {"uid": 7}


def test_begin_end_and_instant_and_counter_events():
    clk = FakeClock()
    tr = obs.Tracer(clock=clk)
    tr.begin("decode", tick=0)
    clk.advance(0.5)
    tr.end("decode")
    tr.instant("preempt", uid=3)
    tr.counter("sched", pending=2, active=4)
    phs = [e.ph for e in tr.events]
    assert phs == ["B", "E", "i", "C"]
    assert tr.events[0].args == {"tick": 0}
    assert tr.events[3].args == {"pending": 2, "active": 4}


def test_async_events_carry_correlation_id():
    tr = obs.Tracer(clock=FakeClock())
    tr.async_begin("request", 42, prompt_len=8)
    tr.async_end("request", 42)
    b, e = tr.events
    assert (b.ph, b.id, e.ph, e.id) == ("b", 42, "e", 42)
    assert b.cat == e.cat == "request"  # async pairs match on (cat, id)


def test_ring_buffer_bounds_memory_and_counts_drops():
    tr = obs.Tracer(capacity=4, clock=FakeClock())
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr.events) == 4
    assert tr.dropped == 6
    assert [e.name for e in tr.events] == ["e6", "e7", "e8", "e9"]
    tr.clear()
    assert tr.events == [] and tr.dropped == 0


def test_chrome_trace_schema_and_export(tmp_path):
    clk = FakeClock()
    tr = obs.Tracer(clock=clk)
    with tr.span("prefill", uid=0):
        clk.advance(0.010)
    tr.async_begin("request", 0)
    tr.async_end("request", 0)
    doc = tr.chrome_trace()
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    for row in doc["traceEvents"]:
        # the keys Perfetto's chrome-trace importer requires
        assert {"name", "ph", "ts", "pid"} <= set(row)
    path = tr.export_chrome(str(tmp_path / "trace.json"))
    loaded = json.load(open(path))
    assert loaded["traceEvents"] == doc["traceEvents"]
    x = loaded["traceEvents"][0]
    assert x["ph"] == "X" and x["dur"] == pytest.approx(10_000)  # us


def test_invalid_capacity_rejected():
    with pytest.raises(ValueError, match="capacity"):
        obs.Tracer(capacity=0)


def test_null_tracer_is_free_and_global_swap_roundtrips():
    null = obs.get_tracer()
    assert null is obs.NULL_TRACER and null.enabled is False
    # one shared span object: the disabled hot path allocates nothing
    s1 = null.span("a", uid=1)
    s2 = null.span("b")
    assert s1 is s2
    with s1:
        pass
    null.begin("x")
    null.end("x")
    null.instant("y")
    null.counter("z", v=1)
    null.async_begin("r", 0)
    null.async_end("r", 0)
    assert null.events == [] and null.chrome_trace()["traceEvents"] == []

    tr = obs.enable_tracing(capacity=16)
    assert obs.get_tracer() is tr and tr.enabled
    obs.disable_tracing()
    assert obs.get_tracer() is obs.NULL_TRACER


# ---------------------------------------------------------------------------
# Metrics


def test_counter_labels_and_monotonicity():
    c = obs.Counter("calls")
    c.inc(op="softmax", impl="pallas")
    c.inc(2, impl="pallas", op="softmax")  # kwarg order must not matter
    c.inc(op="matmul", impl="xla")
    assert c.value(op="softmax", impl="pallas") == 3
    assert c.value(op="matmul", impl="xla") == 1
    assert c.value(op="missing") == 0
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    snap = c.snapshot()
    assert {"labels": {"impl": "pallas", "op": "softmax"}, "value": 3.0} in snap


def test_label_key_is_order_insensitive():
    assert _lkey({"a": 1, "b": 2}) == _lkey({"b": 2, "a": 1})


def test_gauge_set_inc_dec():
    g = obs.Gauge("depth")
    g.set(5)
    g.inc(2)
    g.dec()
    assert g.value() == 6
    g.set(1, slot=3)
    assert g.value(slot=3) == 1 and g.value() == 6


def test_log_buckets_geometric_and_validated():
    bs = obs.log_buckets(1e-3, 1.0, per_decade=1)
    assert bs == pytest.approx((1e-3, 1e-2, 1e-1, 1.0))
    with pytest.raises(ValueError):
        obs.log_buckets(0, 1)
    with pytest.raises(ValueError):
        obs.log_buckets(1e-3, 1.0, per_decade=0)


def test_histogram_exact_moments_and_percentiles():
    h = obs.Histogram("lat", buckets=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 1.5, 3.0, 10.0):  # 10.0 lands in the overflow bucket
        h.observe(v)
    snap = h.snapshot()[0]
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(16.5)  # sums are exact, not bucketed
    assert snap["min"] == 0.5 and snap["max"] == 10.0
    # p50: rank 2.5 falls in the (1, 2] bucket -> interpolated inside it
    assert 1.0 <= h.percentile(50) <= 2.0
    # p100 == observed max even though the top bucket is unbounded
    assert h.percentile(100) == 10.0
    # percentiles clamp to the observed range
    assert h.percentile(0) >= snap["min"]
    assert h.count() == 5 and h.count(route="other") == 0


def test_histogram_deterministic_and_empty_cases():
    a, b = obs.Histogram("a"), obs.Histogram("b")
    for v in (0.001, 0.02, 0.3, 0.3, 4.0):
        a.observe(v)
        b.observe(v)
    for p in (50, 90, 95, 99):
        assert a.percentile(p) == b.percentile(p)  # same obs -> same estimate
    assert obs.Histogram("e").percentile(50) is None
    with pytest.raises(ValueError, match="percentile"):
        a.percentile(101)
    with pytest.raises(ValueError, match="increase"):
        obs.Histogram("bad", buckets=(2.0, 1.0))


def test_histogram_empty_snapshot_never_leaks_inf_sentinels():
    """A zero-count series holds ±inf min/max init sentinels internally;
    the snapshot must mask both (None), stay JSON-serializable, and the
    percentiles must be None rather than interpolated garbage."""
    h = obs.Histogram("lat")
    h.observe(1.0, route="a")  # a sibling series: 'b' stays empty
    h.count(route="b")  # touch only — count() must not create a series
    snap = {s["labels"].get("route"): s for s in h.snapshot()}
    assert "b" not in snap
    h._get({"route": "b"})  # force an empty series into existence
    snap = {s["labels"].get("route"): s for s in h.snapshot()}
    empty = snap["b"]
    assert empty["count"] == 0 and empty["sum"] == 0.0
    assert empty["min"] is None and empty["max"] is None
    assert empty["p50"] is None and empty["p95"] is None and empty["p99"] is None
    out = json.dumps(snap["b"])  # inf would raise / emit non-JSON
    assert "Infinity" not in out
    assert h.percentile(50, route="b") is None


def test_histogram_single_observation_is_exact_everywhere():
    """One sample: every percentile is that exact value — including a
    sample in the unbounded overflow bucket, where interpolation against
    the +inf upper edge must never run."""
    h = obs.Histogram("lat", buckets=(1.0, 2.0))
    h.observe(7.25)  # overflow bucket: hi edge would be +inf
    for p in (0, 50, 95, 99, 100):
        assert h.percentile(p) == 7.25
    snap = h.snapshot()[0]
    assert snap["min"] == snap["max"] == snap["p50"] == 7.25
    json.dumps(snap)
    # a constant multi-sample series is just as exact
    c = obs.Histogram("const", buckets=(1.0, 2.0))
    for _ in range(5):
        c.observe(0.5)
    assert c.percentile(50) == 0.5 and c.percentile(99) == 0.5


def test_registry_get_or_create_and_kind_conflict():
    reg = obs.MetricsRegistry()
    c = reg.counter("x", help="calls")
    assert reg.counter("x") is c  # get-or-create returns the same instance
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")
    reg.gauge("g").set(3)
    reg.histogram("h").observe(0.1)
    snap = reg.snapshot()
    assert set(snap) == {"x", "g", "h"}
    assert snap["g"] == {"kind": "gauge", "series": [{"labels": {}, "value": 3}]}
    assert snap["h"]["series"][0]["count"] == 1
    assert reg.names() == ["g", "h", "x"]
    reg.clear()
    assert reg.snapshot() == {}


def test_default_registry_swap_for_isolation():
    mine = obs.MetricsRegistry()
    prev = obs.set_default_registry(mine)
    try:
        assert obs.default_registry() is mine
    finally:
        obs.set_default_registry(prev)
    assert obs.default_registry() is prev


# ---------------------------------------------------------------------------
# Shared benchmark timing helpers


def test_stopwatch_measures_wall_time():
    from benchmarks._timing import Stopwatch

    with Stopwatch() as sw:
        sum(range(1000))
    assert sw.seconds >= 0.0


def test_time_device_fn_blocks_and_averages():
    import jax.numpy as jnp

    from benchmarks._timing import time_device_fn, time_device_fn_us

    calls = []

    def f():
        calls.append(1)
        return jnp.ones((4,))

    s = time_device_fn(f, iters=3, warmup=2)
    assert s > 0.0
    assert len(calls) == 5  # warmup runs outside the timed region
    assert time_device_fn_us(f, iters=1, warmup=0) == pytest.approx(
        time_device_fn(f, iters=1, warmup=0) * 1e6, rel=5.0
    )
    with pytest.raises(ValueError, match="iters"):
        time_device_fn(f, iters=0)
