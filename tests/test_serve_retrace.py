"""Retrace and transfer regression tests (DESIGN.md §11).

The device-resident engine tick makes two quantitative promises:

* **Bounded retraces** — admission shapes are bucketed to powers of two
  (``serve.paged.bucket_blocks``), so a mixed-length paged workload
  compiles O(log W) admission-write variants, not one per block count;
  and a *repeated* workload compiles nothing at all.
* **Bounded transfers** — a steady tick performs one D2H transfer (the
  ``[S]`` sampled-token vector) and uploads no block-table bytes unless
  the allocator dirtied a row; the ``serve.bytes.h2d`` / ``serve.bytes.d2h``
  counters surface both.

Counters are observables of the engine's *own* jitted callables
(``jit_cache_entries``) — fresh engines own fresh jit caches, so the
repeat-workload assertion reuses one engine instance.
"""

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.serve.engine import ContinuousBatchingEngine, ContinuousConfig
from repro.serve.paged import bucket_blocks

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(3)
MAX_LEN = 40
SLOTS = 3


def test_bucket_blocks_is_pow2_and_clamped():
    assert [bucket_blocks(n, 10) for n in range(1, 11)] == [
        1, 2, 4, 4, 8, 8, 8, 8, 10, 10]
    assert bucket_blocks(0, 10) == 1
    assert bucket_blocks(99, 10) == 10
    assert bucket_blocks(3, 2) == 2  # cap below the bucket


def _engine(cfg, params, **kw):
    return ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=SLOTS, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4, **kw))


def _mixed_workload(cfg, n=20):
    """n mixed-length requests spanning many distinct block counts."""
    lens = [int(x) for x in RNG.integers(2, 33, size=n)]
    prompts = [RNG.integers(0, cfg.vocab_size, (n_,)).astype(np.int32)
               for n_ in lens]
    gens = [int(g) for g in RNG.integers(2, 6, size=n)]
    return prompts, gens


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("granite_8b")
    m = build_model(cfg)
    return cfg, materialize(m.param_specs(), KEY)


def test_mixed_lengths_compile_olog_admission_variants(model):
    cfg, params = model
    eng = _engine(cfg, params)
    prompts, gens = _mixed_workload(cfg)
    raw_blocks = {eng.block_pool.blocks_for_tokens(len(p)) for p in prompts}
    assert len(raw_blocks) >= 6  # the workload really is mixed-length
    for p, g in zip(prompts, gens):
        eng.submit(p, g)
    eng.run()
    # power-of-two bucketing: variants ~ log2(W), not one per block count
    w = eng._slot_blocks
    budget = int(np.ceil(np.log2(w))) + 2  # buckets 1,2,4,...,W
    variants = eng._write_slot_paged._cache_size()
    assert variants <= budget, (
        f"admission write compiled {variants} variants for "
        f"{len(raw_blocks)} distinct block counts (budget {budget})"
    )
    assert variants < len(raw_blocks)


def test_repeat_workload_zero_new_compilations_bounded_d2h(model):
    """Second identical 20-request run on the SAME engine: zero new jit
    entries across every engine-owned callable, and per-tick D2H stays at
    the single sampled-token vector (plus one token per admission)."""
    cfg, params = model
    eng = _engine(cfg, params)
    prompts, gens = _mixed_workload(cfg)

    def run_once():
        t0, a0 = eng.ticks, eng.metrics.counter("serve.requests.admitted").value()
        d0 = eng.metrics.counter("serve.bytes.d2h").value()
        for p, g in zip(prompts, gens):
            eng.submit(p, g)
        eng.run()
        return (eng.ticks - t0,
                eng.metrics.counter("serve.requests.admitted").value() - a0,
                eng.metrics.counter("serve.bytes.d2h").value() - d0)

    run_once()
    entries_after_first = eng.jit_cache_entries()
    assert entries_after_first > 0
    ticks2, admits2, d2h2 = run_once()
    assert eng.jit_cache_entries() == entries_after_first, (
        "a repeated identical workload must not trigger new compilations"
    )
    # per-tick D2H: the [SLOTS] sampled vector; each admission adds the
    # one prefill-sampled token
    assert d2h2 <= ticks2 * SLOTS * 4 + admits2 * 4
    assert d2h2 / max(ticks2, 1) <= (SLOTS + SLOTS) * 4


def test_steady_decode_uploads_no_table_bytes(model):
    """Once admission settles, ticks upload token inputs only: the
    device-resident table is not re-uploaded per tick (the pre-PR
    behaviour was a full [S, W] jnp.asarray every step)."""
    cfg, params = model
    eng = _engine(cfg, params)
    p = RNG.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
    eng.submit(p, 12)
    eng.step()  # admission tick: table rows go up here
    h2d = eng.metrics.counter("serve.bytes.h2d")
    w_bytes = eng._slot_blocks * 4
    deltas = []
    while not eng.scheduler.done():
        before = h2d.value()
        eng.step()
        deltas.append(h2d.value() - before)
    # a tick only pays table bytes when the allocator dirtied a row
    # (block-boundary appends); most steady ticks upload inputs alone
    inputs_only = sum(1 for d in deltas if d <= eng._inputs.size * 4)
    assert inputs_only >= len(deltas) // 2
    assert all(d <= eng._inputs.size * 4 + w_bytes for d in deltas)


def test_gather_bytes_counter_tracks_backend(model):
    """The kv.gather.bytes counter scales with the resolved backend: the
    gather adapters pay the full table window, pallas_paged pays live
    pages — the serve-level form of the BENCH_paged_decode speedup."""
    from repro import ops

    cfg, params = model
    p = RNG.integers(0, cfg.vocab_size, (5,)).astype(np.int32)

    def bytes_per_token(**use):
        with ops.use(**use):
            eng = _engine(cfg, params)
            eng.submit(p, 6)
            eng.run()
        return eng.kv_stats()["gather_bytes_per_token"]

    gathered = bytes_per_token()  # config default: xla gather adapter
    paged = bytes_per_token(paged_attention="pallas_paged")
    assert paged < gathered
    assert gathered / paged >= 1.5
