"""Observability wired through the stack (DESIGN.md §10): request-lifecycle
metrics with an injectable fake clock (deterministic TTFT / ITL /
queue-wait, including the paged preempt-and-requeue path), trace export
from a real serve run, dispatch call counters, guard trip events, and the
disabled-tracer no-overhead smoke check."""

import json
import warnings

import jax
import numpy as np
import pytest

from repro import obs, ops
from repro.configs import get_smoke_config
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.serve.engine import ContinuousBatchingEngine, ContinuousConfig

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)
MAX_LEN = 40


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(autouse=True)
def _clean_globals():
    obs.reset()
    yield
    obs.reset()


def _model_params(arch="granite_8b"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    return cfg, materialize(model.param_specs(), KEY)


def _hist_sum(eng, name):
    (series,) = eng.metrics.snapshot()[name]["series"]
    return series["count"], series["sum"]


# ---------------------------------------------------------------------------
# Request lifecycle with a scripted clock


def test_lifecycle_metrics_deterministic_with_fake_clock():
    cfg, params = _model_params()
    clk = FakeClock()
    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN),
        clock=clk)
    eng.submit(RNG.integers(0, cfg.vocab_size, (5,)), 3)  # t = 0
    clk.advance(1.0)
    eng.step()  # t=1: admit (queue-wait 1.0), token0 (TTFT 1.0), token1 (ITL 0)
    clk.advance(0.5)
    eng.step()  # t=1.5: token2 (ITL 0.5) -> budget 3 reached, finished
    assert eng.scheduler.done()

    assert _hist_sum(eng, "serve.queue_wait_s") == (1, pytest.approx(1.0))
    assert _hist_sum(eng, "serve.ttft_s") == (1, pytest.approx(1.0))
    assert _hist_sum(eng, "serve.itl_s") == (2, pytest.approx(0.5))
    m = eng.metrics
    assert m.counter("serve.requests.submitted").value() == 1
    assert m.counter("serve.requests.admitted").value() == 1
    assert m.counter("serve.requests.finished").value() == 1
    assert m.counter("serve.requests.preempted").value() == 0
    assert m.counter("serve.tokens.generated").value() == 3
    assert m.gauge("serve.queue.depth").value() == 0
    assert m.gauge("serve.slots.active").value() == 0


def test_queue_wait_measures_backpressure():
    """With one slot, the second request's queue wait spans the first
    request's whole occupancy — the scripted clock pins the exact value."""
    cfg, params = _model_params()
    clk = FakeClock()
    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=1, max_len=MAX_LEN),
        clock=clk)
    prompts = [RNG.integers(0, cfg.vocab_size, (4,)) for _ in range(2)]
    eng.submit(prompts[0], 2)
    eng.submit(prompts[1], 2)
    while not eng.scheduler.done():
        clk.advance(1.0)
        eng.step()
    # r0 admitted at t=1 (wait 1) and finishes that same tick (admission
    # token + decode token = its budget of 2), so r1 admits at t=2: wait 2
    (series,) = eng.metrics.snapshot()["serve.queue_wait_s"]["series"]
    assert series["count"] == 2
    assert series["sum"] == pytest.approx(1.0 + 2.0)
    assert series["max"] == pytest.approx(2.0)


def test_paged_preemption_lifecycle_metrics_and_trace():
    """The preempt-and-requeue path: counters track every eviction, TTFT
    is end-to-end (never re-observed after re-admission), queue-wait
    counts each stint, and the trace shows the preemptions."""
    cfg, params = _model_params()
    clk = FakeClock()
    tracer = obs.Tracer(clock=clk)
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=3, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4,
                         kv_pool_blocks=6),
        tracer=tracer, clock=clk)
    for n, g in zip((7, 9, 5), (8, 7, 6)):
        eng.submit(RNG.integers(0, cfg.vocab_size, (n,)), g)
    while not eng.scheduler.done():
        clk.advance(1.0)
        eng.step()

    m = eng.metrics
    preempted = m.counter("serve.requests.preempted").value()
    assert preempted == eng.preemptions > 0
    assert m.counter("serve.requests.finished").value() == 3
    # every admission stint (first + each re-admission) observes one wait;
    # a victim evicted before its prefill never counted as admitted
    admitted = m.counter("serve.requests.admitted").value()
    assert 3 <= admitted <= 3 + preempted
    assert eng.metrics.histogram("serve.queue_wait_s").count() == admitted
    # TTFT is end-to-end: one observation per request, preemption or not
    assert eng.metrics.histogram("serve.ttft_s").count() == 3
    # block-pool accounting flows through the same registry
    assert m.counter("kv.blocks.allocated").value() > 0
    assert m.counter("kv.blocks.freed").value() == \
        m.counter("kv.blocks.allocated").value()  # drained pool
    assert m.gauge("kv.blocks.used").value() == 0

    events = tracer.events
    assert sum(e.name == "serve.preempt" for e in events) == preempted
    for e in events:
        if e.name == "serve.preempt":
            assert "uid" in e.args and "generated" in e.args
    # the evicted request's tokens straddle the preemption: ITL counts
    # every gap, so total tokens == ttft obs + itl obs
    tokens = m.counter("serve.tokens.generated").value()
    assert eng.metrics.histogram("serve.itl_s").count() == tokens - 3


def test_preempt_restamp_counts_every_queue_stint_exactly_once():
    """Regression for the restamp-on-preempt bug: each wait stint lands
    in serve.queue_wait_s exactly once.  The first stint is observed at
    admission (stamp consumed); preemption opens a *new* stint from the
    eviction time, and re-admission observes exactly that gap — nothing
    lost, nothing double-counted."""
    cfg, params = _model_params()
    clk = FakeClock()
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=1, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4),
        clock=clk)
    eng.submit(RNG.integers(0, cfg.vocab_size, (6,)), 8)  # t = 0
    clk.advance(2.0)
    eng.step()  # admit at t=2: first stint 2.0, stamp consumed
    assert _hist_sum(eng, "serve.queue_wait_s") == (1, pytest.approx(2.0))
    clk.advance(1.0)
    slot = next(s for s in eng.scheduler.slots if not s.free)
    eng._preempt(slot)  # t=3: stint already observed -> restamp to now
    clk.advance(4.0)
    eng.step()  # re-admit at t=7: second stint is 7-3=4, not 7-0=7
    assert _hist_sum(eng, "serve.queue_wait_s") == (2, pytest.approx(6.0))
    while not eng.scheduler.done():
        clk.advance(1.0)
        eng.step()
    count, total = _hist_sum(eng, "serve.queue_wait_s")
    assert total == pytest.approx(6.0)  # no stint observed twice
    assert count == eng.metrics.counter("serve.requests.admitted").value()


def test_preempt_before_admission_observe_keeps_the_original_stint():
    """The other half of the fix: a victim evicted before its admission
    observe ran still carries its original stamp — an unconditional
    restamp would silently drop that whole wait from the histogram."""
    cfg, params = _model_params()
    clk = FakeClock()
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=1, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4),
        clock=clk)
    eng.submit(RNG.integers(0, cfg.vocab_size, (6,)), 3)  # t = 0
    clk.advance(1.0)
    (slot,) = eng.scheduler.admit()  # bound, but not yet observed
    eng._preempt(slot)  # t=1: stamp still pending -> must NOT restamp
    clk.advance(2.0)
    eng.step()  # admit at t=3: the single stint spans the whole wait
    assert _hist_sum(eng, "serve.queue_wait_s") == (1, pytest.approx(3.0))
    while not eng.scheduler.done():
        clk.advance(1.0)
        eng.step()
    assert eng.metrics.histogram("serve.queue_wait_s").count() == \
        eng.metrics.counter("serve.requests.admitted").value()


# ---------------------------------------------------------------------------
# Trace export from a serve run (the acceptance-criterion shape)


def test_serve_trace_has_spans_for_every_request_and_loads_as_chrome_json(
        tmp_path):
    cfg, params = _model_params()
    tracer = obs.Tracer()
    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN),
        tracer=tracer)
    uids = [eng.submit(RNG.integers(0, cfg.vocab_size, (4 + i,)), 2)
            for i in range(3)]
    eng.run()

    path = tracer.export_chrome(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)
    # one prefill span per request, carrying its uid
    prefills = by_name["serve.prefill"]
    assert all(p["ph"] == "X" and p["dur"] >= 0 for p in prefills)
    assert sorted(p["args"]["uid"] for p in prefills) == sorted(uids)
    # decode B/E events balance and cover every request's uid
    decode = by_name["serve.decode"]
    assert sum(e["ph"] == "B" for e in decode) == \
        sum(e["ph"] == "E" for e in decode) > 0
    decoded_uids = {u for e in decode if e["ph"] == "B"
                    for u in e["args"]["uids"]}
    assert decoded_uids == set(uids)
    # one async request track per uid, opened and closed
    req = by_name["request"]
    for uid in uids:
        assert [e["ph"] for e in req if e["id"] == uid] == ["b", "e"]
    # scheduler counter samples rendered as a Perfetto counter track
    assert all(e["ph"] == "C" for e in by_name["serve.sched"])


def test_disabled_tracer_records_nothing_during_serve():
    """The no-op tracer smoke check (CI): a full serve run with tracing
    disabled must leave the global null tracer empty — the hot path
    allocates no events when nobody is recording."""
    cfg, params = _model_params()
    assert obs.get_tracer() is obs.NULL_TRACER
    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN))
    assert eng.tracer is obs.NULL_TRACER
    outs = eng.serve([RNG.integers(0, cfg.vocab_size, (4,))] * 2, 2)
    assert all(len(o) == 2 for o in outs)
    assert obs.NULL_TRACER.events == []
    assert obs.NULL_TRACER.chrome_trace()["traceEvents"] == []
    # metrics still flow (they are cheap dict ops, not trace allocations)
    assert eng.metrics.counter("serve.requests.finished").value() == 2


def test_engine_stats_merges_metrics_snapshot():
    cfg, params = _model_params()
    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=1, max_len=MAX_LEN))
    eng.submit(RNG.integers(0, cfg.vocab_size, (4,)), 2)
    eng.run()
    st = eng.stats()
    assert st["ticks"] == eng.ticks
    snap = st["metrics"]
    assert snap["serve.requests.finished"]["series"][0]["value"] == 1
    assert snap["serve.ttft_s"]["series"][0]["count"] == 1


# ---------------------------------------------------------------------------
# Dispatch + guard wiring into the global registry / tracer


def test_dispatch_counts_resolved_backend_labels():
    mine = obs.MetricsRegistry()
    prev = obs.set_default_registry(mine)
    try:
        import jax.numpy as jnp

        x = jnp.ones((2, 8))
        ops.softmax(x)  # default spec -> reference
        with ops.use(softmax="xla"):
            ops.softmax(x, kind="exact")  # resolved impl is the override
        c = mine.counter("ops.dispatch.calls")
        assert c.value(op="softmax", impl="reference") == 1
        assert c.value(op="softmax", impl="xla") == 1
    finally:
        obs.set_default_registry(prev)


def test_guard_trip_increments_counter_and_emits_trace_event():
    mine = obs.MetricsRegistry()
    prev = obs.set_default_registry(mine)
    tracer = obs.enable_tracing()
    try:
        import jax.numpy as jnp

        x = jnp.asarray(RNG.normal(size=(4, 32)) * 4, jnp.float32)
        guard = ops.AccuracyGuard(ops.GuardConfig(tolerance=1e-12))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", ops.GuardTripWarning)
            ops.softmax(x, ops.SoftmaxSpec(), guard=guard)  # star vs exact
        assert guard.tripped
        c = mine.counter("ops.guard.trips")
        assert c.value(op="softmax", impl="reference") == 1
        assert mine.counter("ops.guard.calls").value(op="softmax") == 1
        assert mine.counter("ops.guard.checks").value(op="softmax") == 1
        assert mine.counter("ops.guard.fallbacks").value(op="softmax") == 1
        trips = [e for e in tracer.events if e.name == "guard.trip"]
        assert len(trips) == 1
        ev = trips[0]
        assert ev.cat == "guard" and ev.args["op"] == "softmax"
        assert ev.args["error"] > ev.args["tolerance"]
        assert ev.args["fallback"] == "reference"
    finally:
        obs.set_default_registry(prev)
        obs.disable_tracing()


def test_engine_guard_counters_reach_engine_stats_and_registry():
    """ContinuousConfig(guard=) + obs: the engine's lifetime guard mirrors
    its counters into the global registry alongside stats()["guard"]."""
    mine = obs.MetricsRegistry()
    prev = obs.set_default_registry(mine)
    try:
        cfg, params = _model_params()
        eng = ContinuousBatchingEngine(
            cfg, params,
            ContinuousConfig(num_slots=1, max_len=MAX_LEN, temperature=0.7,
                             guard=ops.GuardConfig(sample_every=1)))
        eng.submit(RNG.integers(0, cfg.vocab_size, (4,)), 2)
        eng.run()
        st = eng.stats()
        assert st["guard"]["calls"] > 0
        assert mine.counter("ops.guard.calls").value(op="softmax") == \
            st["guard"]["calls"]
    finally:
        obs.set_default_registry(prev)
