"""Quantized paged KV cache (DESIGN.md §13).

The claims pinned here:

1. **Roundtrip bound** — encode/decode error is ≤ ``scale / 2`` per
   element for int8 (the rounding grid) and ≤ ``16 * scale`` for
   fp8_e4m3 (half the widest e4m3 ulp), property-swept over magnitudes.
2. **Kernel = oracle** — ``pallas_paged`` with in-kernel dequant matches
   the gather backends (which dequantize the gathered codes — the exact
   same ``codes * scale`` expression) to float32 roundoff, NOT to a loose
   quantization tolerance: both paths read identical operands.
3. **Gather-freedom survives quantization** — the quantized kernel's
   jaxpr still contains no ``[S, W*bs, Hkv, D]`` operand at any
   precision; scales ride scalar prefetch.
4. **Dispatch guardrails** — ``kv_scales`` is required iff the spec says
   quantized; the guard's fallback strips ``kv_dtype`` like it strips
   faults.
5. **Engine parity** — int8 serving through the kernel is token-identical
   to int8 serving through the gather oracle (dense, ring-wrap, M-RoPE
   archs); fp32 paged serving is untouched; int8 bytes/token ≤ 0.55x
   fp32 (the CI compression gate's in-repo twin).
6. **Deprecation sweep** — no in-repo caller imports the retired
   ``kernels/*/ops.py`` shims (``tests/test_kernel_shims.py`` pins the
   shims themselves and is the one allowed importer).
"""

import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.configs import get_smoke_config
from repro.core import kvquant
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.ops.guard import clean_spec
from repro.serve import paged as serve_paged
from repro.serve.engine import (
    ContinuousBatchingEngine,
    ContinuousConfig,
)

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(23)
MAX_LEN = 40
QUANT_DTYPES = ("int8", "fp8_e4m3")


# ---------------------------------------------------------------------------
# core.kvquant: roundtrip property + dtype plumbing


@pytest.mark.parametrize("kv_dtype", QUANT_DTYPES)
@pytest.mark.parametrize("magnitude", [1e-3, 1.0, 30.0])
def test_roundtrip_error_bound(kv_dtype, magnitude):
    """Per-element |decode(encode(x)) - x| stays inside the grid bound."""
    x = jnp.asarray(RNG.normal(size=(4, 16, 2, 32)) * magnitude, jnp.float32)
    codes, scale = kvquant.quantize_blocks(x, kv_dtype)
    assert codes.dtype == kvquant.storage_dtype(kv_dtype)
    assert scale.shape == (4, 2) and scale.dtype == jnp.float32
    back = kvquant.decode(codes, scale[:, None, :, None])
    err = np.asarray(jnp.abs(back - x))
    # int8: round-to-nearest on a uniform grid -> half a step.  fp8_e4m3:
    # scaling maps absmax to 448, so the widest ulp in play is 32 -> 16.
    bound = 0.5 if kv_dtype == "int8" else 16.0
    # * (1 + 1e-5): the decode multiply itself rounds in float32, which can
    # push an exactly-half-ulp case a few f32 ulps past the analytic bound
    limit = bound * np.asarray(scale)[:, None, :, None] * (1 + 1e-5) + 1e-12
    assert np.all(err <= limit)


def test_zero_block_roundtrips_to_exact_zero():
    x = jnp.zeros((2, 8, 2, 16), jnp.float32)
    for kv_dtype in QUANT_DTYPES:
        codes, scale = kvquant.quantize_blocks(x, kv_dtype)
        back = np.asarray(kvquant.decode(codes, scale[:, None, :, None]))
        assert np.all(back == 0.0) and np.all(np.isfinite(back))


def test_fp8_overflow_clips_instead_of_nan():
    """Values past an undersized scale's range must clip, never NaN — the
    stale-stamp decode path writes rows bigger than the stamped absmax."""
    stale_scale = jnp.float32(0.01)
    codes = kvquant.encode(jnp.asarray([1e4, -1e4]), stale_scale, "fp8_e4m3")
    back = np.asarray(kvquant.decode(codes, stale_scale))
    assert np.all(np.isfinite(back))
    assert back[0] == pytest.approx(448 * 0.01) and back[1] == -back[0]


def test_dtype_mapping_roundtrip():
    for kv_dtype in QUANT_DTYPES:
        assert kvquant.dtype_of(kvquant.storage_dtype(kv_dtype)) == kv_dtype
    assert kvquant.dtype_of(jnp.float32) == "fp32"
    assert kvquant.dtype_of(jnp.bfloat16) == "fp32"
    with pytest.raises(ValueError, match="fp32"):
        kvquant.storage_dtype("fp32")
    with pytest.raises(ValueError, match="kv_dtype"):
        kvquant.validate_kv_dtype("int4")


def test_spec_and_pool_validate_kv_dtype():
    with pytest.raises(ValueError, match="kv_dtype"):
        ops.PagedAttentionSpec(kv_dtype="int4")
    with pytest.raises(ValueError, match="kv_dtype"):
        serve_paged.BlockPool(4, 4, kv_dtype="int4")
    # the allocator's jax-free mirror of the dtype list must not drift
    assert serve_paged.KV_DTYPES == kvquant.KV_DTYPES


def test_guard_clean_spec_strips_quantization_and_faults():
    fault = ops.FaultModel(stuck_on_rate=0.01, seed=0)
    sm = clean_spec(ops.SoftmaxSpec(impl="pallas", fault=fault), "reference")
    assert sm.impl == "reference" and sm.fault is None
    pa = clean_spec(ops.PagedAttentionSpec(kv_dtype="int8"), "xla")
    assert pa.impl == "xla" and pa.kv_dtype == "fp32"


# ---------------------------------------------------------------------------
# op level: kernel vs dequant oracle, guardrails, gather-freedom


def _quantized_operands(kv_dtype, s=3, w=4, bs=8, hq=4, hkv=2, d=16,
                        lens=(6, 25, 11)):
    n = s * w + 1
    q = jnp.asarray(RNG.normal(size=(s, 1, hq, d)), jnp.float32)
    kf = jnp.asarray(RNG.normal(size=(n, bs, hkv, d)), jnp.float32)
    vf = jnp.asarray(RNG.normal(size=(n, bs, hkv, d)), jnp.float32)
    kp, ks = kvquant.quantize_blocks(kf, kv_dtype)
    vp, vs = kvquant.quantize_blocks(vf, kv_dtype)
    perm = RNG.permutation(np.arange(1, n))
    tables = jnp.asarray(perm[: s * w].reshape(s, w), jnp.int32)
    kvl = jnp.asarray(lens, jnp.int32)
    return q, kp, vp, (ks, vs), tables, kvl


@pytest.mark.parametrize("kv_dtype", QUANT_DTYPES)
@pytest.mark.parametrize("kind", ["star", "exact"])
def test_kernel_parity_vs_dequant_oracle(kv_dtype, kind):
    """Float32-roundoff parity: both paths evaluate codes * scale."""
    q, kp, vp, scales, tables, kvl = _quantized_operands(kv_dtype)
    def mk(impl):
        return ops.PagedAttentionSpec(
            impl=impl, block_size=8, kv_dtype=kv_dtype,
            softmax=ops.SoftmaxSpec(kind=kind),
        )
    ref = ops.paged_attention(q, kp, vp, tables, mk("xla"),
                              kv_valid_len=kvl, kv_scales=scales)
    out = ops.paged_attention(q, kp, vp, tables, mk("pallas_paged"),
                              kv_valid_len=kvl, kv_scales=scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


def test_kernel_parity_ring_clamp_quantized():
    q, kp, vp, scales, tables, kvl = _quantized_operands(
        "int8", lens=(30, 32, 12))
    def mk(impl):
        return ops.PagedAttentionSpec(impl=impl, block_size=8,
                                      kv_dtype="int8")
    ref = ops.paged_attention(q, kp, vp, tables, mk("reference"),
                              kv_valid_len=kvl, kv_len=16, kv_scales=scales)
    out = ops.paged_attention(q, kp, vp, tables, mk("pallas_paged"),
                              kv_valid_len=kvl, kv_len=16, kv_scales=scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-6)


def test_int8_output_close_to_fp32_reference():
    """The accuracy claim itself, pinned: quantizing KV moves the attention
    output by a bounded amount, it does not change its shape/scale."""
    q, kp, vp, scales, tables, kvl = _quantized_operands("int8")
    spec8 = ops.PagedAttentionSpec(impl="xla", block_size=8, kv_dtype="int8")
    out8 = ops.paged_attention(q, kp, vp, tables, spec8,
                               kv_valid_len=kvl, kv_scales=scales)
    kf = kvquant.decode(kp, scales[0][:, None, :, None])
    vf = kvquant.decode(vp, scales[1][:, None, :, None])
    spec32 = ops.PagedAttentionSpec(impl="xla", block_size=8)
    out32 = ops.paged_attention(q, kf, vf, tables, spec32, kv_valid_len=kvl)
    # identical codes: dequantized-operand attention == quantized attention
    np.testing.assert_allclose(np.asarray(out8), np.asarray(out32), atol=3e-6)


def test_dispatch_requires_scales_iff_quantized():
    q, kp, vp, scales, tables, kvl = _quantized_operands("int8")
    spec = ops.PagedAttentionSpec(impl="xla", block_size=8, kv_dtype="int8")
    with pytest.raises(ops.OpDispatchError, match="kv_scales"):
        ops.paged_attention(q, kp, vp, tables, spec, kv_valid_len=kvl)
    fp32 = ops.PagedAttentionSpec(impl="xla", block_size=8)
    with pytest.raises(ops.OpDispatchError, match="kv_scales"):
        ops.paged_attention(
            q, kp.astype(jnp.float32), vp.astype(jnp.float32), tables, fp32,
            kv_valid_len=kvl, kv_scales=scales,
        )


def _jaxpr_avals(jaxpr, acc):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            acc.append(v.aval)
        for val in eqn.params.values():
            if isinstance(val, jax.core.ClosedJaxpr):
                _jaxpr_avals(val.jaxpr, acc)
            elif isinstance(val, jax.core.Jaxpr):
                _jaxpr_avals(val, acc)
            elif isinstance(val, (tuple, list)):
                for item in val:
                    if isinstance(item, jax.core.ClosedJaxpr):
                        _jaxpr_avals(item.jaxpr, acc)
                    elif isinstance(item, jax.core.Jaxpr):
                        _jaxpr_avals(item, acc)
    return acc


@pytest.mark.parametrize("kv_dtype", QUANT_DTYPES)
def test_quantized_kernel_never_materializes_gathered_window(kv_dtype):
    """No [S, W*bs, Hkv, D] operand at ANY dtype: the dequantized window
    must not exist either — scales ride scalar prefetch, dequant happens
    one page at a time in VMEM."""
    q, kp, vp, scales, tables, kvl = _quantized_operands(kv_dtype)
    s, w = tables.shape
    _, bs, hkv, d = kp.shape
    spec = ops.PagedAttentionSpec(
        impl="pallas_paged", block_size=bs, kv_dtype=kv_dtype)

    def call(q, kp, vp, ks, vs, tables, kvl):
        return ops.paged_attention(q, kp, vp, tables, spec,
                                   kv_valid_len=kvl, kv_scales=(ks, vs))

    avals = _jaxpr_avals(
        jax.make_jaxpr(call)(q, kp, vp, *scales, tables, kvl), [])
    gathered = (s, w * bs, hkv, d)
    assert not any(getattr(a, "shape", None) == gathered for a in avals)


def test_counted_bytes_int8_meets_compression_target():
    """The kernel_bench acceptance shape in-repo: counted int8 bytes/token
    (codes + scale rows) ≤ 0.55x the fp32 bytes/token at pool-256/live-8."""
    common = dict(impl="pallas_paged", table_width=16, block_size=16,
                  live_lens=[8] * 8, num_kv_heads=2, head_dim=64)
    fp32 = ops.paged_gather_bytes(dtype_bytes=4, **common)
    int8 = ops.paged_gather_bytes(
        dtype_bytes=1, scale_bytes_per_block=8 * 2, **common)
    assert int8 / fp32 <= 0.55


# ---------------------------------------------------------------------------
# model/cache layer: write-path quantization + scale lifecycle


def test_paged_cache_leaves_and_write_roundtrip():
    cfg = get_smoke_config("granite_8b")
    model = build_model(cfg)
    pool = model.init_paged_cache(9, 4, 2, kv_dtype="int8")
    assert pool["layers"]["k"].dtype == jnp.int8
    assert pool["layers"]["k_scale"].shape == (
        cfg.num_layers, 9, cfg.num_kv_heads)
    # fp32 pools carry no scale leaves at all — the layout marker
    assert "k_scale" not in model.init_paged_cache(9, 4, 2)["layers"]

    params = materialize(model.param_specs(), KEY)
    # max_len 8 -> an 8-row prefill cache, exactly the 2 blocks the table holds
    _, cache = model.prefill(
        params, jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, 7)), jnp.int32),
        8)
    pool = model.write_slot_paged(pool, cache, 0, jnp.asarray([1, 2], jnp.int32))
    k = np.asarray(cache["layers"]["k"])[:, 0, :7]
    got = kvquant.decode(
        pool["layers"]["k"][:, [1, 2]],
        pool["layers"]["k_scale"][:, [1, 2]][:, :, None, :, None],
    )
    got = np.asarray(got).reshape(k.shape[0], 8, *k.shape[2:])[:, :7]
    scale = np.asarray(pool["layers"]["k_scale"][:, [1, 2]])
    assert np.max(np.abs(got - k)) <= 0.5 * scale.max() + 1e-12


def test_copy_block_moves_scale_rows():
    cfg = get_smoke_config("granite_8b")
    model = build_model(cfg)
    pool = model.init_paged_cache(5, 4, 2, kv_dtype="int8")
    layers = dict(pool["layers"])
    layers["k_scale"] = layers["k_scale"].at[:, 2].set(7.0)
    layers["v_scale"] = layers["v_scale"].at[:, 2].set(3.0)
    pool = {**pool, "layers": layers}
    pool = model.copy_block(pool, jnp.int32(2), jnp.int32(4))
    assert np.all(np.asarray(pool["layers"]["k_scale"][:, 4]) == 7.0)
    assert np.all(np.asarray(pool["layers"]["v_scale"][:, 4]) == 3.0)


# ---------------------------------------------------------------------------
# engine: greedy token parity at int8, fp32 untouched, byte accounting


def _model_params(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    return cfg, materialize(model.param_specs(), KEY)


def _serve(cfg, params, prompts, gens, kv_dtype, impl, frontends=None,
           **cb_kw):
    cb = ContinuousConfig(num_slots=2, max_len=MAX_LEN, kv_layout="paged",
                          kv_block_size=4, kv_dtype=kv_dtype, **cb_kw)
    fes = frontends or [{} for _ in prompts]
    with ops.use(paged_attention=impl):
        eng = ContinuousBatchingEngine(cfg, params, cb)
        uids = [eng.submit(p, g, **fe)
                for p, g, fe in zip(prompts, gens, fes)]
        done = eng.run()
    return [done[u] for u in uids], eng


@pytest.mark.parametrize("arch,lens", [
    ("granite_8b", (5, 11, 8, 3)),       # dense append path
    ("mixtral_8x22b", (20, 11, 18, 3)),  # window=16 ring: stamps must
                                         # survive wrap-around laps
])
def test_engine_int8_kernel_matches_int8_oracle(arch, lens):
    cfg, params = _model_params(arch)
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in lens]
    gens = [4, 2, 5, 3]
    got, eng = _serve(cfg, params, prompts, gens, "int8", "pallas_paged")
    want, _ = _serve(cfg, params, prompts, gens, "int8", "xla")
    assert got == want
    st = eng.kv_stats()
    assert st["kv_dtype"] == "int8" and st["gather_bytes_per_token"] > 0


def test_engine_int8_vlm_mrope_parity():
    cfg, params = _model_params("qwen2_vl_7b")
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 9)]
    pe = [{"patch_embeds": RNG.standard_normal(
        (1, cfg.num_patches, cfg.frontend_dim)).astype(np.float32)}
        for _ in prompts]
    got, _ = _serve(cfg, params, prompts, [3, 2], "int8", "pallas_paged", pe)
    want, _ = _serve(cfg, params, prompts, [3, 2], "int8", "xla", pe)
    assert got == want


def test_engine_int8_prefix_cache_parity():
    """Shared prefix blocks carry their scales: adoption + CoW discipline
    must keep kernel and oracle token-identical."""
    cfg, params = _model_params("granite_8b")
    prefix = RNG.integers(0, cfg.vocab_size, (9,)).astype(np.int32)
    suffix = RNG.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
    prompts = [prefix, np.concatenate([prefix, suffix])]

    def serve_sequential(impl):
        # two phases so the first prompt's blocks are in the trie before
        # the second prompt prefills — that second prefill must adopt the
        # shared (quantized) prefix blocks
        cb = ContinuousConfig(num_slots=2, max_len=MAX_LEN,
                              kv_layout="paged", kv_block_size=4,
                              kv_dtype="int8", prefix_cache=True,
                              prefill_chunk_tokens=8)
        with ops.use(paged_attention=impl):
            eng = ContinuousBatchingEngine(cfg, params, cb)
            u0 = eng.submit(prompts[0], 3)
            first = eng.run()[u0]
            u1 = eng.submit(prompts[1], 3)
            second = eng.run()[u1]
        return [first, second], eng

    got, eng = serve_sequential("pallas_paged")
    want, _ = serve_sequential("xla")
    assert got == want
    assert eng.kv_stats()["prefix"]["hits"] == 1


def test_engine_fp32_unaffected_and_int8_compresses():
    """fp32 serving is byte-identical to before this feature (no scale
    leaves, same tokens as the oracle) and the engine-counted bytes/token
    hits the ≤ 0.55x acceptance ratio."""
    cfg, params = _model_params("granite_8b")
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 8)]
    got, e32 = _serve(cfg, params, prompts, [3, 3], "fp32", "pallas_paged")
    want, _ = _serve(cfg, params, prompts, [3, 3], "fp32", "xla")
    assert got == want
    assert "k_scale" not in e32.pool["layers"]
    _, e8 = _serve(cfg, params, prompts, [3, 3], "int8", "pallas_paged")
    b32 = e32.kv_stats()["kv_bytes_per_token"]
    b8 = e8.kv_stats()["kv_bytes_per_token"]
    assert b8 <= 0.55 * b32
    # row bytes derive from the actual leaf dtypes (satellite: kv_row_bytes)
    assert e8.kv_row_bytes() * 4 == e32.kv_row_bytes()


def test_engine_rejects_quantized_dense_layout():
    cfg, params = _model_params("granite_8b")
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingEngine(
            cfg, params,
            ContinuousConfig(num_slots=2, max_len=MAX_LEN,
                             kv_layout="dense", kv_dtype="int8"))


# ---------------------------------------------------------------------------
# deprecation sweep: the kernels/*/ops.py shims have no in-repo importers


def test_no_in_repo_shim_importers():
    """The shims are retired: only ``tests/test_kernel_shims.py`` (which
    pins the shims' own deprecation behaviour) may import them.  Grep the
    tree so a regressed import fails here, not in review."""
    root = pathlib.Path(__file__).resolve().parents[1]
    pat = re.compile(
        r"repro\.kernels\.(star_softmax|flash_star|crossbar_matmul|ssd_scan)"
        r"\.ops\b")
    allowed = {"tests/test_kernel_shims.py"}
    offenders = []
    for sub in ("src", "tests", "benchmarks"):
        for path in (root / sub).rglob("*.py"):
            rel = path.relative_to(root).as_posix()
            if rel in allowed or path.name == "ops.py":
                continue
            if pat.search(path.read_text()):
                offenders.append(rel)
    assert not offenders, f"retired shim imported by: {offenders}"
