"""End-to-end dry-run regression: one real cell through the 512-device
launch path in a subprocess (the cheapest cell: mamba2 decode)."""

import json
import os
import subprocess
import sys
import tempfile

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_dryrun_cell_end_to_end():
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        env.pop("XLA_FLAGS", None)  # dryrun sets its own
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "mamba2_130m", "--shape", "decode_32k",
             "--mesh", "single", "--out", d, "--no-probes"],
            capture_output=True, text=True, env=env, timeout=900,
        )
        assert r.returncode == 0, r.stderr[-3000:]
        rec = json.load(open(os.path.join(d, "mamba2_130m_decode_32k_single.json")))
        assert rec["ok"] and rec["chips"] == 256
        assert rec["flops_per_dev"] > 0 and rec["bytes_per_dev"] > 0
        assert rec["dominant"] in ("compute", "memory", "collective")
        assert rec["peak_bytes_per_dev"] < 16e9  # fits v5e HBM
