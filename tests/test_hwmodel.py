"""The analytical hardware model must land inside the paper's envelope."""

import pytest

from repro.hwmodel.star_engine import fig3, system_efficiency, table1


def test_table1_bands():
    t = table1()
    # paper: 0.06x area, 0.05x power vs CMOS baseline
    assert t["ours_model"]["area"] == pytest.approx(0.06, abs=0.03)
    assert t["ours_model"]["power"] == pytest.approx(0.05, abs=0.03)
    # strictly better than Softermax on both axes
    assert t["ours_model"]["area"] < t["softermax"]["area"]
    assert t["ours_model"]["power"] < t["softermax"]["power"]
    # paper: 0.20x / 0.44x vs Softermax
    assert t["vs_softermax_model"]["area"] == pytest.approx(0.20, abs=0.08)
    assert t["vs_softermax_model"]["power"] == pytest.approx(0.44, abs=0.12)


def test_fig3_bands():
    f = fig3()
    assert f["star_model"] == pytest.approx(612.66, rel=0.25)
    assert f["retransformer_model"] == pytest.approx(467.7, rel=0.25)
    assert 1.0 < f["star_vs_retransformer_model"] < 1.7  # paper: 1.31


def test_softmax_share_grows_with_seq():
    shares = [
        system_efficiency(s, softmax_on_rram=False, vector_pipeline=False)["softmax_share"]
        for s in (128, 256, 512, 1024)
    ]
    assert shares == sorted(shares)


def test_both_contributions_needed():
    """Each of the paper's two ideas contributes; together they are best."""
    base = system_efficiency(128, False, False)["gops_per_w"]
    sm = system_efficiency(128, True, False)["gops_per_w"]
    pipe = system_efficiency(128, False, True)["gops_per_w"]
    both = system_efficiency(128, True, True)["gops_per_w"]
    assert sm > base and pipe > base
    assert both > sm and both > pipe
