"""SlotScheduler unit tests: admission, backpressure, reuse, completion.

Pure host-side — no jax arrays, no model."""

import numpy as np
import pytest

from repro.serve.scheduler import Request, SlotScheduler


def submit_n(sched, n, gen=4):
    return [sched.submit(np.arange(1, 4), gen) for _ in range(n)]


def test_request_validation():
    with pytest.raises(ValueError):
        Request(0, np.zeros((0,), np.int32), 4)
    with pytest.raises(ValueError):
        Request(0, np.zeros((2, 2), np.int32), 4)
    with pytest.raises(ValueError):
        Request(0, np.arange(3), 0)
    with pytest.raises(ValueError):
        SlotScheduler(0)


def test_fifo_admission_and_backpressure():
    sched = SlotScheduler(2)
    uids = submit_n(sched, 5)
    admitted = sched.admit()
    # pool of 2: only the first two requests get slots, rest wait in line
    assert [s.request.uid for s in admitted] == uids[:2]
    assert len(sched.pending) == 3
    assert sched.free_slots() == []
    # a second admit with a full pool is a no-op (backpressure, no drops)
    assert sched.admit() == []
    assert len(sched.pending) == 3


def test_slot_reuse_after_retire():
    sched = SlotScheduler(2)
    uids = submit_n(sched, 4, gen=2)
    (s0, s1) = sched.admit()
    # finish slot 0's request -> slot is immediately reusable
    sched.record_token(s0, 7)
    assert sched.record_token(s0, 8) is True  # budget of 2 reached
    sched.retire(s0)
    assert sched.finished[uids[0]] == [7, 8]
    admitted = sched.admit()
    assert len(admitted) == 1
    assert admitted[0].index == s0.index  # same physical slot, new request
    assert admitted[0].request.uid == uids[2]
    assert admitted[0].generated == []  # lifecycle state reset on bind


def test_completion_by_eos():
    sched = SlotScheduler(1)
    uid = sched.submit(np.arange(5), 100, eos_id=9)
    (slot,) = sched.admit()
    assert sched.record_token(slot, 3) is False
    assert sched.record_token(slot, 9) is True  # EOS beats the budget
    sched.retire(slot)
    assert sched.finished[uid] == [3, 9]
    assert sched.done()


def test_done_tracks_pending_and_active():
    sched = SlotScheduler(1)
    assert sched.done()
    sched.submit(np.arange(3), 1)
    assert not sched.done()  # pending
    (slot,) = sched.admit()
    assert not sched.done()  # active
    sched.record_token(slot, 0)
    sched.retire(slot)
    assert sched.done()


def test_admit_caps_at_free_slots():
    sched = SlotScheduler(3)
    submit_n(sched, 2)
    admitted = sched.admit()
    assert len(admitted) == 2
    assert len(sched.free_slots()) == 1
    assert len(sched.active_slots) == 2
