"""Training-loop integration: convergence, crash/restart, preemption,
straggler watchdog."""

import os
import signal
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed.fault import FailureInjector, StragglerWatchdog
from repro.train.loop import LoopConfig, run_train
from repro.train.step import TrainConfig


def test_loss_decreases():
    cfg = get_smoke_config("granite_8b")
    res = run_train(
        cfg, TrainConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60),
        LoopConfig(num_steps=40, batch=8, seq_len=64, log_every=100),
        log_fn=lambda *_: None,
    )
    first = np.mean([h["loss"] for h in res["history"][:5]])
    last = np.mean([h["loss"] for h in res["history"][-5:]])
    assert last < first - 0.3, (first, last)


def test_crash_restart_resumes_bitwise():
    cfg = get_smoke_config("granite_8b")
    tc = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    with tempfile.TemporaryDirectory() as d:
        lc = LoopConfig(num_steps=12, batch=4, seq_len=32, ckpt_dir=d,
                        ckpt_every=5, log_every=100)
        # uninterrupted run
        ref = run_train(cfg, tc, LoopConfig(num_steps=12, batch=4, seq_len=32,
                                            log_every=100), log_fn=lambda *_: None)
        # crashed + resumed run
        with pytest.raises(RuntimeError):
            run_train(cfg, tc, lc, failure_injector=FailureInjector(fail_at_step=8),
                      log_fn=lambda *_: None)
        res = run_train(cfg, tc, lc, log_fn=lambda *_: None)
        assert res["final_step"] == 12
        # identical final loss (deterministic data + optimizer)
        assert res["history"][-1]["loss"] == pytest.approx(
            ref["history"][-1]["loss"], abs=1e-6
        )


def test_straggler_watchdog():
    w = StragglerWatchdog(threshold=2.0, warmup=2)
    for _ in range(5):
        assert not w.observe(0.10)
    assert w.observe(0.50)  # 5x EMA -> straggler
    assert len(w.events) == 1
    # EMA not poisoned by the straggler
    assert w.ema == pytest.approx(0.10, rel=0.2)


def test_preemption_checkpoint(tmp_path):
    """SIGTERM mid-run -> loop checkpoints and exits cleanly."""
    cfg = get_smoke_config("granite_8b")
    d = str(tmp_path)

    sent = {"done": False}

    def log_and_preempt(msg):
        # send ourselves SIGTERM after the first logged step
        if not sent["done"] and "step" in msg:
            sent["done"] = True
            os.kill(os.getpid(), signal.SIGTERM)

    res = run_train(
        cfg, TrainConfig(),
        LoopConfig(num_steps=50, batch=4, seq_len=32, ckpt_dir=d,
                   ckpt_every=1000, log_every=1),
        log_fn=log_and_preempt,
    )
    assert res["final_step"] < 50  # stopped early
    from repro.checkpoint import checkpointer
    assert checkpointer.latest_step(d) == res["final_step"]


def test_microbatched_grads_match_full():
    import jax
    from repro.models.param import materialize
    from repro.models.registry import build_model
    from repro.train.state import init_state
    from repro.train.step import make_train_step
    from repro.data.synthetic import make_batch

    cfg = get_smoke_config("granite_8b")
    model = build_model(cfg)
    state = init_state(model.param_specs(), jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, batch=8, seq_len=32, step=0).items()}
    s1, m1 = jax.jit(make_train_step(model, TrainConfig(microbatches=1)))(state, batch)
    s4, m4 = jax.jit(make_train_step(model, TrainConfig(microbatches=4)))(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), s1["params"], s4["params"])
    assert max(jax.tree.leaves(d)) < 5e-5
