"""Kernel sweep: fused SSD chunk scan vs the model's chunked reference."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.kernels.ssd_scan.ref import ssd_scan_ref

RNG = np.random.default_rng(13)


def ssd_scan_op(xdt, a, bm, cm, *, chunk=128):
    """Dispatch-layer call the retired ``ops.py`` shim used to wrap."""
    return ops.ssd_scan(xdt, a, bm, cm, ops.ScanSpec(impl="pallas", chunk=chunk))


def make(b, t, h, p, n):
    xdt = jnp.asarray(RNG.normal(size=(b, t, h, p)), jnp.float32)
    a = -jnp.abs(jnp.asarray(RNG.normal(size=(b, t, h)) * 0.1, jnp.float32))
    bm = jnp.asarray(RNG.normal(size=(b, t, n)) * 0.3, jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(b, t, n)) * 0.3, jnp.float32)
    return xdt, a, bm, cm


@pytest.mark.parametrize("dims", [
    (2, 64, 4, 16, 32, 16),
    (1, 100, 3, 8, 16, 32),   # ragged tail (100 % 32 != 0)
    (2, 128, 24, 64, 128, 128),  # mamba2-130m geometry
    (1, 33, 2, 8, 8, 64),     # chunk > T
])
def test_kernel_matches_ref(dims):
    b, t, h, p, n, chunk = dims
    xdt, a, bm, cm = make(b, t, h, p, n)
    y0, h0 = ssd_scan_ref(xdt, a, bm, cm, chunk=chunk)
    y1, h1 = ssd_scan_op(xdt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=1e-4)


def test_chunk_size_invariance():
    xdt, a, bm, cm = make(1, 96, 2, 8, 16)
    outs = [np.asarray(ssd_scan_op(xdt, a, bm, cm, chunk=c)[0]) for c in (16, 32, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4)


def test_state_continuation():
    """Kernel's final state continues the recurrence exactly: running the
    second half seeded with the first half's state == running it all."""
    xdt, a, bm, cm = make(1, 64, 2, 8, 16)
    y_full, h_full = ssd_scan_ref(xdt, a, bm, cm, chunk=16)
    _, h_half = ssd_scan_op(xdt[:, :32], a[:, :32], bm[:, :32], cm[:, :32], chunk=16)
    y2, h2 = ssd_scan_ref(
        xdt[:, 32:], a[:, 32:], bm[:, 32:], cm[:, 32:], chunk=16, h0=h_half
    )
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 32:]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4)
