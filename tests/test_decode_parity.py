"""Serving-path integration: prefill + N decode steps must reproduce the
full-forward logits (validates KV caches, ring buffers, RoPE offsets,
SSM/LRU states, cross-attention caches) for every architecture."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.param import materialize
from repro.models.registry import build_model

RNG = np.random.default_rng(7)
KEY = jax.random.PRNGKey(1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = dataclasses.replace(
        get_smoke_config(arch), softmax_kind="exact", capacity_factor=16.0
    )
    model = build_model(cfg)
    params = materialize(model.param_specs(), KEY)
    B, T, G = 1, 24, 6
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, T + G)), jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.num_patches, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "encdec":
        kw["src_embeds"] = jnp.asarray(
            RNG.normal(size=(B, 16, cfg.frontend_dim)), jnp.float32)

    if cfg.family == "encdec":
        full = model.forward(params, {"src_embeds": kw["src_embeds"], "tokens": tokens})
    else:
        full = model.forward(params, tokens, **kw)

    maxlen = T + G + (cfg.num_patches if cfg.family == "vlm" else 0)
    logits, cache = model.prefill(params, tokens[:, :T], max_len=maxlen, **kw)
    off = cfg.num_patches if cfg.family == "vlm" else 0
    errs = [float(jnp.max(jnp.abs(logits[:, -1] - full[:, T - 1 + off])))]
    for i in range(G):
        step_logits, cache = model.decode_step(params, cache, tokens[:, T + i:T + i + 1])
        errs.append(float(jnp.max(jnp.abs(step_logits[:, 0] - full[:, T + i + off]))))
    assert max(errs) < 2e-3, f"{arch}: {errs}"


@pytest.mark.parametrize("arch", ["mixtral_8x22b", "recurrentgemma_2b"])
def test_windowed_decode_beyond_window(arch):
    """Ring-buffer caches keep working after the window wraps."""
    cfg = dataclasses.replace(
        get_smoke_config(arch), softmax_kind="exact", capacity_factor=16.0
    )
    model = build_model(cfg)
    params = materialize(model.param_specs(), KEY)
    window = cfg.sliding_window or cfg.local_window
    T = window + 4  # prefill longer than the window
    G = 5
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (1, T + G)), jnp.int32)
    full = model.forward(params, tokens)
    logits, cache = model.prefill(params, tokens[:, :T], max_len=T + G)
    errs = [float(jnp.max(jnp.abs(logits[:, -1] - full[:, T - 1])))]
    for i in range(G):
        sl, cache = model.decode_step(params, cache, tokens[:, T + i:T + i + 1])
        errs.append(float(jnp.max(jnp.abs(sl[:, 0] - full[:, T + i]))))
    assert max(errs) < 2e-3, errs
