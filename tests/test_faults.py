"""Fault-injection subsystem tests (DESIGN.md §9).

Covers the three contracts the subsystem makes:

* **seeded determinism** — a ``FaultModel`` (seed + site tags) fully
  determines every mask and noise draw: bit-identical across repeated
  calls, under jit, and across *processes* (keys derive via crc32 fold-in,
  never the process-salted ``hash()``); different seeds give different
  realizations;
* **backend parity** — reference and pallas apply the *same* realization
  (tight allclose; reduction order may differ), and attention's xla
  backend routes faulty calls through the materialized path so it is
  bit-identical to reference;
* **the accuracy guard** — pushing the stuck-at rate past the spec
  tolerance demonstrably trips the guard: structured warning, fallback to
  the clean backend, counters; and guarded dispatch under jit fails with
  an actionable error instead of silently not checking.
"""

import functools
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.core.attention import blocked_attention, SoftmaxConfig
from repro.core.fixedpoint import FixedPointFormat
from repro.hwmodel import faults as faults_lib
from repro.hwmodel.faults import FaultModel
from repro.ops.registry import CapabilityError, OpDispatchError

FMT = FixedPointFormat(6, 3)
FAULT = FaultModel(
    g_sigma=0.05,
    stuck_on_rate=0.01,
    stuck_off_rate=0.01,
    adc_offset_sigma=0.1,
    read_disturb=0.01,
    seed=7,
)
SEVERE = FaultModel(stuck_on_rate=0.6, stuck_off_rate=0.2, seed=3)

KEY = jax.random.PRNGKey(0)
X = jax.random.normal(KEY, (4, 64)) * 3.0

MODES = ("gather", "onehot", "histogram")


# ---------------------------------------------------------------------------
# FaultModel / spec hygiene


def test_fault_model_validation():
    with pytest.raises(ValueError):
        FaultModel(g_sigma=-0.1)
    with pytest.raises(ValueError):
        FaultModel(stuck_on_rate=1.5)
    with pytest.raises(ValueError):
        FaultModel(stuck_on_rate=0.7, stuck_off_rate=0.7)  # sum > 1
    assert FaultModel().is_null
    assert faults_lib.is_null(None)
    assert not FAULT.is_null
    assert FaultModel.after_reads(100, 1e-4).read_disturb == pytest.approx(0.01)


def test_null_fault_normalizes_to_none_in_specs():
    # a null model must not split jit caches or spec equality
    spec = ops.SoftmaxSpec(fault=FaultModel(seed=42))
    assert spec.fault is None
    assert spec == ops.SoftmaxSpec()
    assert ops.MatmulSpec(fault=FaultModel()).fault is None
    aspec = ops.AttentionSpec(fault=FaultModel())
    assert aspec.fault is None and aspec.softmax.fault is None


def test_exact_kind_rejects_fault():
    with pytest.raises(ValueError, match="exact"):
        ops.SoftmaxSpec(kind="exact", fault=FAULT)


def test_attention_spec_folds_fault_into_softmax():
    spec = ops.AttentionSpec(fault=FAULT)
    assert spec.softmax.fault == FAULT
    # the attention-level field wins over a pre-set nested fault
    other = FaultModel(g_sigma=0.3, seed=1)
    spec = ops.AttentionSpec(
        softmax=ops.SoftmaxSpec(fault=other), fault=FAULT
    )
    assert spec.softmax.fault == FAULT


# ---------------------------------------------------------------------------
# seeded determinism


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("impl", ["reference", "pallas"])
def test_softmax_bit_identical_across_calls_and_jit(impl, mode):
    """Same spec => bit-identical, repeated and under jit.

    Within one compilation regime the seeded realization is exactly
    reproducible.  Across regimes (an eager reference call vs the same
    call inside jit) XLA's fusion-time FMA contraction can move float
    results by 1 ulp, so eager-vs-jit is asserted bitwise for the pallas
    backend (whose realization is always computed under its own jit) and
    to 1-ulp tolerance for the eager reference engine.
    """
    spec = ops.SoftmaxSpec(impl=impl, mode=mode, precision=FMT, fault=FAULT)
    a = ops.softmax(X, spec)
    b = ops.softmax(X, spec)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @functools.partial(jax.jit, static_argnames=("spec",))
    def f(x, spec):
        return ops.softmax(x, spec)

    c = f(X, spec)
    d = f(X, spec)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(d))
    if impl == "pallas":
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    else:
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-6)


@pytest.mark.parametrize("mode", MODES)
def test_softmax_backend_parity_under_faults(mode):
    # reference and pallas stream the same seeded realization; only the
    # reduction order may differ (one-hot matmul lookups are exact)
    ref = ops.softmax(
        X, ops.SoftmaxSpec(impl="reference", mode=mode, precision=FMT, fault=FAULT)
    )
    pal = ops.softmax(
        X, ops.SoftmaxSpec(impl="pallas", mode=mode, precision=FMT, fault=FAULT)
    )
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), atol=1e-6)


def test_different_seeds_differ_and_fault_changes_output():
    clean = ops.softmax(X, ops.SoftmaxSpec(precision=FMT))
    a = ops.softmax(X, ops.SoftmaxSpec(precision=FMT, fault=FAULT))
    b = ops.softmax(
        X,
        ops.SoftmaxSpec(
            precision=FMT, fault=dataclasses_replace_seed(FAULT, 8)
        ),
    )
    assert not np.array_equal(np.asarray(a), np.asarray(clean))
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def dataclasses_replace_seed(fault: FaultModel, seed: int) -> FaultModel:
    import dataclasses

    return dataclasses.replace(fault, seed=seed)


def test_stuck_masks_disjoint_and_rate_accurate():
    heavy = FaultModel(stuck_on_rate=0.3, stuck_off_rate=0.2, seed=5)
    on, off = faults_lib.stuck_masks(
        faults_lib.fault_key(heavy, "softmax/lut"), (256, 256), heavy
    )
    on, off = np.asarray(on), np.asarray(off)
    assert not (on & off).any()
    assert abs(on.mean() - 0.3) < 0.02
    assert abs(off.mean() - 0.2) < 0.02


def test_cam_remap_targets_working_rows():
    remap = faults_lib.cam_remap(FMT, SEVERE)
    assert remap is not None
    remap = np.asarray(remap)
    on, off = faults_lib.stuck_masks(
        faults_lib.fault_key(SEVERE, "softmax/cam"), (FMT.num_levels,), SEVERE
    )
    broken = np.asarray(on | off)
    assert not broken[remap].any()  # every remap target is a working row
    working = np.arange(FMT.num_levels)[~broken]
    np.testing.assert_array_equal(remap[~broken], working)  # identity there


def test_cross_process_determinism():
    # keys derive from crc32 of the tag, never hash(): a fresh interpreter
    # must reproduce the realization bit-for-bit
    prog = (
        "import jax, jax.numpy as jnp, numpy as np\n"
        "from repro.hwmodel.faults import FaultModel, apply_cell_faults\n"
        "f = FaultModel(g_sigma=0.05, stuck_on_rate=0.01, stuck_off_rate=0.01,\n"
        "               adc_offset_sigma=0.1, read_disturb=0.01, seed=7)\n"
        "v = apply_cell_faults(jnp.ones((16, 16)), f, 'softmax/lut', g_on=1.0)\n"
        "print(np.asarray(v).tobytes().hex())\n"
    )
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env=env, check=True,
    )
    here = faults_lib.apply_cell_faults(
        jnp.ones((16, 16)), FAULT, "softmax/lut", g_on=1.0
    )
    assert out.stdout.strip() == np.asarray(here).tobytes().hex()


# ---------------------------------------------------------------------------
# attention under faults


def _qkv():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    shape = (2, 16, 4, 32)
    return tuple(jax.random.normal(k, shape) for k in ks)


def test_attention_reference_xla_bit_identical_under_faults():
    q, k, v = _qkv()
    spec = ops.AttentionSpec(
        impl="reference", causal=True, fault=FAULT,
        softmax=ops.SoftmaxSpec(precision=FMT),
    )
    a = ops.attention(q, k, v, spec)
    b = ops.attention(q, k, v, spec, impl="xla")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_blocked_attention_rejects_faults():
    # the online-rescale identity lut[a]*lut[b] == lut[a+b] breaks under
    # per-cell faults: the blocked pipeline must refuse, not drift
    q, k, v = _qkv()
    cfg = SoftmaxConfig.from_spec(ops.SoftmaxSpec(precision=FMT, fault=FAULT))
    with pytest.raises(ValueError, match="fault"):
        blocked_attention(q, k, v, softmax=cfg, block_size=8)


def test_pallas_attention_capability_error():
    q, k, v = _qkv()
    spec = ops.AttentionSpec(impl="pallas", fault=FAULT)
    with pytest.raises(CapabilityError, match="softmax.fault"):
        ops.attention(q, k, v, spec)


# ---------------------------------------------------------------------------
# matmul under faults


def test_matmul_fault_deterministic_and_distinct():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (32, 256))
    w = jax.random.normal(k2, (256, 192))
    spec = ops.MatmulSpec(impl="hwmodel", fault=FAULT)
    a = ops.matmul(x, w, spec)
    b = ops.matmul(x, w, spec)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    clean = ops.matmul(x, w, ops.MatmulSpec(impl="hwmodel"))
    assert not np.array_equal(np.asarray(a), np.asarray(clean))


def test_matmul_xla_capability_error():
    x = jnp.ones((4, 8))
    w = jnp.ones((8, 4))
    with pytest.raises(CapabilityError, match="fault"):
        ops.matmul(x, w, ops.MatmulSpec(impl="xla", fault=FAULT))


# ---------------------------------------------------------------------------
# the accuracy guard


def test_guard_trips_past_tolerance_and_falls_back():
    # SEVERE stuck rates push max-abs error far past the (6,3) spec bound
    spec = ops.SoftmaxSpec(impl="reference", precision=FMT, fault=SEVERE)
    guard = ops.AccuracyGuard(ops.GuardConfig())
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = ops.softmax(X, spec, guard=guard)
    trips = [w for w in rec if issubclass(w.category, ops.GuardTripWarning)]
    assert len(trips) == 1
    w = trips[0].message
    assert w.op == "softmax" and w.error > w.tolerance
    s = guard.stats()
    assert s == {
        "calls": 1, "checks": 1, "trips": 1, "fallbacks": 1,
        "tripped": True, "last_error": s["last_error"],
    }
    assert s["last_error"] > spec.tolerance()
    # the fallback output is the clean backend's (fault stripped, kind kept)
    clean = ops.softmax(X, ops.SoftmaxSpec(impl="reference", precision=FMT))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))


def test_guard_latches_after_first_trip():
    guard = ops.AccuracyGuard(ops.GuardConfig())
    spec = ops.SoftmaxSpec(impl="reference", precision=FMT, fault=SEVERE)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        ops.softmax(X, spec, guard=guard)
        ops.softmax(X, spec, guard=guard)
    s = guard.stats()
    assert s["calls"] == 2 and s["checks"] == 1  # latched: no second check
    assert s["fallbacks"] == 2


def test_guard_passes_clean_specs_through():
    guard = ops.AccuracyGuard(ops.GuardConfig())
    spec = ops.SoftmaxSpec(impl="reference", precision=FMT)
    out = ops.softmax(X, spec, guard=guard)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ops.softmax(X, spec))
    )
    s = guard.stats()
    assert s["trips"] == 0 and not s["tripped"] and s["checks"] == 1


def test_guard_matmul_trips_on_severe_faults():
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    x = jax.random.normal(k1, (16, 256))
    w = jax.random.normal(k2, (256, 128))
    guard = ops.AccuracyGuard(ops.GuardConfig(matmul_rtol=0.05))
    spec = ops.MatmulSpec(impl="hwmodel", fault=SEVERE)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = ops.matmul(x, w, spec, guard=guard)
    assert any(issubclass(w.category, ops.GuardTripWarning) for w in rec)
    assert guard.stats()["fallbacks"] == 1
    # clean fallback impl is the exact path
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(x @ w), rtol=1e-5, atol=1e-5
    )


def test_guard_rejects_traced_calls():
    guard = ops.AccuracyGuard(ops.GuardConfig())
    spec = ops.SoftmaxSpec(impl="reference", precision=FMT, fault=FAULT)

    @functools.partial(jax.jit, static_argnames=("spec",))
    def f(x, spec):
        return ops.softmax(x, spec, guard=guard)

    with pytest.raises(OpDispatchError, match="jit"):
        f(X, spec)


def test_guard_config_validation():
    with pytest.raises(ValueError):
        ops.GuardConfig(sample_every=0)
    with pytest.raises(ValueError):
        ops.GuardConfig(tolerance=-1.0)
    with pytest.raises(OpDispatchError):
        ops.softmax(X, ops.SoftmaxSpec(), guard="yes")  # type: ignore


def test_guard_sampling_skips_unsampled_calls():
    guard = ops.AccuracyGuard(ops.GuardConfig(sample_every=3, latch=False))
    spec = ops.SoftmaxSpec(impl="reference", precision=FMT, fault=SEVERE)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for _ in range(6):
            ops.softmax(X, spec, guard=guard)
    s = guard.stats()
    assert s["calls"] == 6 and s["checks"] == 2 and s["trips"] == 2


# ---------------------------------------------------------------------------
# the serving engine surfaces guard counters


def test_engine_stats_surface_guard_counters():
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.param import materialize
    from repro.models.registry import build_model
    from repro.serve.engine import ContinuousBatchingEngine, ContinuousConfig

    rng = np.random.default_rng(0)
    cfg = get_smoke_config("granite_8b")
    cfg = dataclasses.replace(
        cfg, softmax=dataclasses.replace(cfg.softmax_spec, fault=SEVERE)
    )
    params = materialize(build_model(cfg).param_specs(), jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(
            num_slots=2, max_len=48, temperature=1.0,
            guard=ops.GuardConfig(tolerance=0.02),
        ),
    )
    prompts = [
        rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32),
        rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32),
    ]
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        outs = eng.serve(prompts, 4)
    assert all(len(o) == 4 for o in outs)
    assert any(issubclass(w.category, ops.GuardTripWarning) for w in rec)
    stats = eng.stats()
    assert stats["guard"]["trips"] >= 1
    assert stats["guard"]["fallbacks"] >= 1
    assert stats["guard"]["tripped"]
    assert stats["kv"]["layout"] == "dense" and stats["ticks"] >= 1
