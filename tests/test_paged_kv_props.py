"""Property-based invariant suite for the paged KV block pool (DESIGN.md §8).

Random opcode sequences drive ``BlockPool`` through interleaved
allocate / append / release / fork / copy-on-write traffic — the same mix
the continuous-batching engine generates under preemption pressure — and
the allocator invariants are checked after **every** operation:

* no double-free: the free list holds no duplicates and never a live block;
* refcounts match the live tables exactly (a block's refcount == how many
  tables reference it);
* conservation: free blocks + distinct live blocks == usable pool size;
* the reserved scratch block 0 is never handed out, never freed, never in
  any table.

Runs under hypothesis when installed; otherwise the deterministic
``_prop_fallback`` sweep (boundary draws + seeded random draws) exercises
the same properties so tier-1 never depends on an optional package.
"""

from collections import Counter

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on hypothesis-less CI
    from _prop_fallback import given, settings, st

from repro.serve.paged import SCRATCH_BLOCK, BlockPool, PoolExhausted

POOL_BLOCKS = 9  # 8 usable + scratch: small enough to hit exhaustion often
BLOCK_SIZE = 4


def check_invariants(pool: BlockPool) -> None:
    free = pool._free
    tables = pool._tables
    refcount = pool._refcount

    # no double-free: free list is duplicate-free and disjoint from live
    assert len(free) == len(set(free)), f"duplicate ids in free list: {free}"
    live = set()
    for table in tables.values():
        live.update(table)
    assert not (set(free) & live), "block is both free and table-referenced"

    # refcounts match the live tables exactly
    expected = Counter()
    for table in tables.values():
        expected.update(table)
    assert dict(refcount) == dict(expected), (refcount, expected)

    # conservation: every usable block is free xor live
    assert len(free) + len(live) == pool.usable_blocks
    assert pool.free_blocks + pool.used_blocks == pool.usable_blocks

    # scratch block 0 never escapes
    assert SCRATCH_BLOCK not in free
    assert SCRATCH_BLOCK not in live
    assert all(1 <= b < pool.num_blocks for b in free)
    assert all(1 <= b < pool.num_blocks for b in live)


def drive(pool: BlockPool, opcodes) -> None:
    """Decode each opcode into one pool operation (guarded so every random
    sequence is valid traffic) and re-check all invariants after it."""
    next_uid = 0
    live = []  # uids owning a table, admission order
    for code in opcodes:
        op, arg = code % 5, code // 5
        if op == 0:  # admission: allocate 1-3 fresh blocks
            n = 1 + arg % 3
            if pool.can_allocate(n):
                blocks = pool.allocate(next_uid, n)
                assert len(blocks) == n
                live.append(next_uid)
                next_uid += 1
        elif op == 1 and live:  # decode growth: append one block
            uid = live[arg % len(live)]
            if pool.can_allocate(1):
                pool.append(uid)
        elif op == 2 and live:  # retire / preempt: release the table
            uid = live.pop(arg % len(live))
            pool.release(uid)
        elif op == 3 and live:  # beam fork: share the parent's blocks
            parent = live[arg % len(live)]
            pool.fork(parent, next_uid)
            live.append(next_uid)
            next_uid += 1
        elif op == 4 and live:  # append-only write: privatize last block
            uid = live[arg % len(live)]
            last = pool.table(uid)[-1]
            if pool.refcount(last) == 1 or pool.can_allocate(1):
                pool.ensure_writable(uid)
        check_invariants(pool)
    # drain: releasing everything must return the pool to pristine
    for uid in live:
        pool.release(uid)
        check_invariants(pool)
    assert pool.free_blocks == pool.usable_blocks
    assert not pool._tables and not pool._refcount


@settings(max_examples=200, deadline=None)
@given(opcodes=st.lists(st.integers(0, 10_000), min_size=1, max_size=80))
def test_pool_invariants_random_traffic(opcodes):
    drive(BlockPool(POOL_BLOCKS, BLOCK_SIZE), opcodes)


@settings(max_examples=100, deadline=None)
@given(opcodes=st.lists(st.integers(0, 10_000), min_size=1, max_size=120))
def test_pool_invariants_tiny_pool(opcodes):
    # 2 usable blocks: every sequence lives at the exhaustion boundary
    drive(BlockPool(3, BLOCK_SIZE), opcodes)


# -- directed edge cases the random driver cannot guarantee to hit ----------


def test_scratch_block_never_allocated_under_full_drain():
    pool = BlockPool(POOL_BLOCKS, BLOCK_SIZE)
    blocks = pool.allocate(1, pool.usable_blocks)  # take the whole pool
    assert SCRATCH_BLOCK not in blocks
    assert sorted(blocks) == list(range(1, POOL_BLOCKS))
    with pytest.raises(PoolExhausted):
        pool.allocate(2, 1)
    pool.release(1)
    check_invariants(pool)


def test_release_is_not_double_freeable():
    pool = BlockPool(POOL_BLOCKS, BLOCK_SIZE)
    pool.allocate(1, 2)
    pool.release(1)
    with pytest.raises(KeyError):
        pool.release(1)  # table is gone: no path to a second free
    check_invariants(pool)


def test_fork_keeps_shared_blocks_live():
    pool = BlockPool(POOL_BLOCKS, BLOCK_SIZE)
    parent = pool.allocate(1, 3)
    child = pool.fork(1, 2)
    assert child == parent
    assert all(pool.refcount(b) == 2 for b in parent)
    pool.release(1)  # parent retires; child still pins every block
    check_invariants(pool)
    assert pool.used_blocks == 3
    pool.release(2)
    check_invariants(pool)
    assert pool.free_blocks == pool.usable_blocks


def test_copy_on_write_privatizes_only_the_last_block():
    pool = BlockPool(POOL_BLOCKS, BLOCK_SIZE)
    table = pool.allocate(1, 2)
    pool.fork(1, 2)
    copy = pool.ensure_writable(2)
    assert copy is not None
    src, dst = copy
    assert src == table[-1] and dst not in table
    check_invariants(pool)
    # prefix block still shared, last block exclusive per branch
    assert pool.refcount(table[0]) == 2
    assert pool.refcount(table[-1]) == 1 and pool.refcount(dst) == 1
    assert pool.ensure_writable(2) is None  # already exclusive
    check_invariants(pool)
