"""Property-based invariant suite for the paged KV block pool (DESIGN.md §8).

Random opcode sequences drive ``BlockPool`` through interleaved
allocate / append / release / fork / copy-on-write traffic — the same mix
the continuous-batching engine generates under preemption pressure — and
the allocator invariants are checked after **every** operation:

* no double-free: the free list holds no duplicates and never a live block;
* refcounts match the live tables exactly (a block's refcount == how many
  tables reference it);
* conservation: free blocks + distinct live blocks == usable pool size;
* the reserved scratch block 0 is never handed out, never freed, never in
  any table.

With a ``PrefixCache`` attached (DESIGN.md §12) the machine additionally
drives trie traffic — prefix lookup/adopt admissions, inserts, LRU
eviction — and the invariants extend to the trie's bare pins:

* a pinned block is never on the free list (pins are references);
* refcounts == table references + trie pins, exactly;
* draining every table and clearing the trie returns the pool to
  pristine (no leaked pin survives the trie that took it).

Runs under hypothesis when installed; otherwise the deterministic
``_prop_fallback`` sweep (boundary draws + seeded random draws) exercises
the same properties so tier-1 never depends on an optional package.
"""

from collections import Counter

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on hypothesis-less CI
    from _prop_fallback import given, settings, st

from repro.serve.paged import SCRATCH_BLOCK, BlockPool, PoolExhausted, PrefixCache

POOL_BLOCKS = 9  # 8 usable + scratch: small enough to hit exhaustion often
BLOCK_SIZE = 4


def _trie_pins(trie: PrefixCache) -> Counter:
    """One pin per node, by construction of insert/evict/clear."""
    pins = Counter()
    stack = list(trie.root.children.values())
    while stack:
        node = stack.pop()
        pins[node.block] += 1
        stack.extend(node.children.values())
    return pins


def check_invariants(pool: BlockPool, pins: Counter = None) -> None:
    free = pool._free
    tables = pool._tables
    refcount = pool._refcount
    pins = pins or Counter()

    # no double-free: free list is duplicate-free and disjoint from live
    # (table-referenced or trie-pinned)
    assert len(free) == len(set(free)), f"duplicate ids in free list: {free}"
    live = set(pins)
    for table in tables.values():
        live.update(table)
    assert not (set(free) & live), "block is both free and referenced"

    # refcounts match the live tables + trie pins exactly
    expected = Counter(pins)
    for table in tables.values():
        expected.update(table)
    assert dict(refcount) == dict(expected), (refcount, expected)

    # conservation: every usable block is free xor live
    assert len(free) + len(live) == pool.usable_blocks
    assert pool.free_blocks + pool.used_blocks == pool.usable_blocks

    # scratch block 0 never escapes
    assert SCRATCH_BLOCK not in free
    assert SCRATCH_BLOCK not in live
    assert all(1 <= b < pool.num_blocks for b in free)
    assert all(1 <= b < pool.num_blocks for b in live)

    # scale pages share their block's lifecycle exactly (DESIGN.md §13):
    # every allocated block of a quantized pool owns a live scale page,
    # no freed block keeps one, and fp32 pools carry none at all
    if pool.quantized:
        assert pool._scale_pages == set(refcount), (
            pool._scale_pages, set(refcount))
    else:
        assert not pool._scale_pages


def drive(pool: BlockPool, opcodes) -> None:
    """Decode each opcode into one pool operation (guarded so every random
    sequence is valid traffic) and re-check all invariants after it."""
    next_uid = 0
    live = []  # uids owning a table, admission order
    for code in opcodes:
        op, arg = code % 5, code // 5
        if op == 0:  # admission: allocate 1-3 fresh blocks
            n = 1 + arg % 3
            if pool.can_allocate(n):
                blocks = pool.allocate(next_uid, n)
                assert len(blocks) == n
                live.append(next_uid)
                next_uid += 1
        elif op == 1 and live:  # decode growth: append one block
            uid = live[arg % len(live)]
            if pool.can_allocate(1):
                pool.append(uid)
        elif op == 2 and live:  # retire / preempt: release the table
            uid = live.pop(arg % len(live))
            pool.release(uid)
        elif op == 3 and live:  # beam fork: share the parent's blocks
            parent = live[arg % len(live)]
            pool.fork(parent, next_uid)
            live.append(next_uid)
            next_uid += 1
        elif op == 4 and live:  # append-only write: privatize last block
            uid = live[arg % len(live)]
            last = pool.table(uid)[-1]
            if pool.refcount(last) == 1 or pool.can_allocate(1):
                pool.ensure_writable(uid)
        check_invariants(pool)
    # drain: releasing everything must return the pool to pristine
    for uid in live:
        pool.release(uid)
        check_invariants(pool)
    assert pool.free_blocks == pool.usable_blocks
    assert not pool._tables and not pool._refcount


@settings(max_examples=200, deadline=None)
@given(opcodes=st.lists(st.integers(0, 10_000), min_size=1, max_size=80))
def test_pool_invariants_random_traffic(opcodes):
    drive(BlockPool(POOL_BLOCKS, BLOCK_SIZE), opcodes)


@settings(max_examples=100, deadline=None)
@given(opcodes=st.lists(st.integers(0, 10_000), min_size=1, max_size=120))
def test_pool_invariants_tiny_pool(opcodes):
    # 2 usable blocks: every sequence lives at the exhaustion boundary
    drive(BlockPool(3, BLOCK_SIZE), opcodes)


@settings(max_examples=100, deadline=None)
@given(opcodes=st.lists(st.integers(0, 10_000), min_size=1, max_size=80))
def test_pool_invariants_quantized_scale_pages(opcodes):
    # same traffic, int8 layout: every op must keep scale pages in
    # lockstep with block refcounts (checked inside check_invariants)
    drive(BlockPool(POOL_BLOCKS, BLOCK_SIZE, kv_dtype="int8"), opcodes)


# -- directed edge cases the random driver cannot guarantee to hit ----------


def test_scratch_block_never_allocated_under_full_drain():
    pool = BlockPool(POOL_BLOCKS, BLOCK_SIZE)
    blocks = pool.allocate(1, pool.usable_blocks)  # take the whole pool
    assert SCRATCH_BLOCK not in blocks
    assert sorted(blocks) == list(range(1, POOL_BLOCKS))
    with pytest.raises(PoolExhausted):
        pool.allocate(2, 1)
    pool.release(1)
    check_invariants(pool)


def test_release_is_not_double_freeable():
    pool = BlockPool(POOL_BLOCKS, BLOCK_SIZE)
    pool.allocate(1, 2)
    pool.release(1)
    with pytest.raises(KeyError):
        pool.release(1)  # table is gone: no path to a second free
    check_invariants(pool)


def test_fork_keeps_shared_blocks_live():
    pool = BlockPool(POOL_BLOCKS, BLOCK_SIZE)
    parent = pool.allocate(1, 3)
    child = pool.fork(1, 2)
    assert child == parent
    assert all(pool.refcount(b) == 2 for b in parent)
    pool.release(1)  # parent retires; child still pins every block
    check_invariants(pool)
    assert pool.used_blocks == 3
    pool.release(2)
    check_invariants(pool)
    assert pool.free_blocks == pool.usable_blocks


def test_copy_on_write_privatizes_only_the_last_block():
    pool = BlockPool(POOL_BLOCKS, BLOCK_SIZE)
    table = pool.allocate(1, 2)
    pool.fork(1, 2)
    copy = pool.ensure_writable(2)
    assert copy is not None
    src, dst = copy
    assert src == table[-1] and dst not in table
    check_invariants(pool)
    # prefix block still shared, last block exclusive per branch
    assert pool.refcount(table[0]) == 2
    assert pool.refcount(table[-1]) == 1 and pool.refcount(dst) == 1
    assert pool.ensure_writable(2) is None  # already exclusive
    check_invariants(pool)


# -- prefix-trie machine: pool + PrefixCache traffic (DESIGN.md §12) --------


def _stream_tokens(stream: int, n_tokens: int):
    """Deterministic token stream per id: streams 2k and 2k+1 share their
    first chunk and diverge at the second, so the trie grows chains *and*
    branch points under random traffic."""
    return [
        ((stream // 2) + (i // BLOCK_SIZE) * (1 + stream % 2)) % 5
        for i in range(n_tokens)
    ]


def drive_prefix(pool: BlockPool, opcodes) -> None:
    """Interleave prefix-cache admissions (lookup + adopt + insert) with
    releases, LRU eviction, and plain allocations; re-check the extended
    pin-aware invariants after every operation."""
    trie = PrefixCache(pool)
    next_uid = 0
    live = []  # uids owning a table
    for code in opcodes:
        op, arg = code % 4, code // 4
        if op == 0:  # prefix admission: longest cached prefix + suffix
            tokens = _stream_tokens(arg % 4, BLOCK_SIZE * (1 + arg % 3) + 2)
            blocks, rows = trie.lookup(tokens)
            need = pool.blocks_for_tokens(len(tokens)) - len(blocks)
            if pool.can_allocate(need):
                pool.adopt(next_uid, blocks)
                for _ in range(need):
                    pool.append(next_uid)
                check_invariants(pool, _trie_pins(trie))
                # "prefill done": index the full blocks (idempotent for
                # chunks already cached — first writer wins)
                trie.insert(tokens, pool.table(next_uid))
                live.append(next_uid)
                next_uid += 1
        elif op == 1 and live:  # retire: pins must keep cached blocks
            pool.release(live.pop(arg % len(live)))
        elif op == 2:  # pool pressure: reclaim one LRU leaf (or refuse)
            before = pool.free_blocks
            if trie.evict_one():
                assert pool.free_blocks == before + 1
        elif op == 3:  # LRU touch: a lookup that may miss entirely
            trie.lookup(_stream_tokens(arg % 4, BLOCK_SIZE + 1))
        check_invariants(pool, _trie_pins(trie))

    # drain every table: trie pins alone must keep their blocks live
    for uid in live:
        pool.release(uid)
        check_invariants(pool, _trie_pins(trie))
    pins = _trie_pins(trie)
    assert all(pool.refcount(b) == n for b, n in pins.items())
    assert pool.used_blocks == len(pins)
    # clearing the trie drops the last references: pool back to pristine
    trie.clear()
    check_invariants(pool)
    assert pool.free_blocks == pool.usable_blocks
    assert not pool._tables and not pool._refcount


@settings(max_examples=200, deadline=None)
@given(opcodes=st.lists(st.integers(0, 10_000), min_size=1, max_size=80))
def test_prefix_trie_invariants_random_traffic(opcodes):
    drive_prefix(BlockPool(POOL_BLOCKS, BLOCK_SIZE), opcodes)


@settings(max_examples=100, deadline=None)
@given(opcodes=st.lists(st.integers(0, 10_000), min_size=1, max_size=120))
def test_prefix_trie_invariants_tiny_pool(opcodes):
    # 3 usable blocks: adoption + insert constantly at the boundary
    drive_prefix(BlockPool(4, BLOCK_SIZE), opcodes)


@settings(max_examples=100, deadline=None)
@given(opcodes=st.lists(st.integers(0, 10_000), min_size=1, max_size=80))
def test_prefix_trie_invariants_quantized(opcodes):
    # trie pins / adoption / eviction with int8 scale pages: a shared or
    # pinned block's scale page must survive exactly as long as the block
    drive_prefix(BlockPool(POOL_BLOCKS, BLOCK_SIZE, kv_dtype="int8"), opcodes)


def test_trie_pin_is_never_freed_while_referenced():
    """Directed: a block both pinned and table-referenced survives either
    single release; only dropping *both* references frees it."""
    pool = BlockPool(POOL_BLOCKS, BLOCK_SIZE)
    trie = PrefixCache(pool)
    tokens = _stream_tokens(0, BLOCK_SIZE * 2)
    table = pool.allocate(0, 2)
    trie.insert(tokens, table)
    check_invariants(pool, _trie_pins(trie))
    pool.release(0)  # trie pins keep both blocks
    assert pool.used_blocks == 2
    adopted = pool.adopt(1, trie.lookup(tokens + [9])[0])
    assert adopted == table
    assert not trie.evict_one()  # every leaf shared with uid 1: refused
    pool.release(1)
    assert trie.evict_one() and pool.used_blocks == 1
    check_invariants(pool, _trie_pins(trie))
    trie.clear()
    assert pool.free_blocks == pool.usable_blocks
