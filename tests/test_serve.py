"""Serving engine tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.serve.engine import ServeConfig, ServeEngine

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)


def engine(arch="granite_8b", **kw):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = materialize(model.param_specs(), KEY)
    return cfg, ServeEngine(cfg, params, ServeConfig(**kw))


def test_greedy_deterministic():
    cfg, eng = engine(max_len=64, temperature=0.0)
    prompts = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    a, _ = eng.generate(prompts, 6)
    b, _ = eng.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 6)
    assert int(jnp.max(a)) < cfg.vocab_size  # padding vocab never sampled


def test_star_sampling_valid_tokens():
    cfg, eng = engine(max_len=64, temperature=1.0)
    prompts = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    toks, info = eng.generate(prompts, 8, key=jax.random.PRNGKey(7))
    assert int(jnp.max(toks)) < cfg.vocab_size
    assert info["cache_len"] == 15  # prompt(8) + gen(8) - 1 (last token unconsumed)


def test_serve_moe_and_ssm():
    for arch in ("granite_moe_1b_a400m", "mamba2_130m"):
        cfg, eng = engine(arch, max_len=48, temperature=0.0)
        prompts = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
        toks, _ = eng.generate(prompts, 4)
        assert toks.shape == (2, 4)
