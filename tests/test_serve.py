"""Serving engine tests: lockstep baseline + continuous batching parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.serve.engine import (
    ContinuousBatchingEngine,
    ContinuousConfig,
    ServeConfig,
    ServeEngine,
)

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)


def engine(arch="granite_8b", **kw):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = materialize(model.param_specs(), KEY)
    return cfg, ServeEngine(cfg, params, ServeConfig(**kw))


def _model_params(arch="granite_8b"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    return cfg, materialize(model.param_specs(), KEY)


def test_greedy_deterministic():
    cfg, eng = engine(max_len=64, temperature=0.0)
    prompts = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    a, _ = eng.generate(prompts, 6)
    b, _ = eng.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 6)
    assert int(jnp.max(a)) < cfg.vocab_size  # padding vocab never sampled


def test_star_sampling_valid_tokens():
    cfg, eng = engine(max_len=64, temperature=1.0)
    prompts = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    toks, info = eng.generate(prompts, 8, key=jax.random.PRNGKey(7))
    assert int(jnp.max(toks)) < cfg.vocab_size
    assert info["cache_len"] == 15  # prompt(8) + gen(8) - 1 (last token unconsumed)


def test_serve_moe_and_ssm():
    for arch in ("granite_moe_1b_a400m", "mamba2_130m"):
        cfg, eng = engine(arch, max_len=48, temperature=0.0)
        prompts = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
        toks, _ = eng.generate(prompts, 4)
        assert toks.shape == (2, 4)


# ---------------------------------------------------------------------------
# Continuous batching


MAX_LEN = 40


@pytest.mark.parametrize("arch,lens", [
    ("granite_8b", (5, 11, 8, 3)),          # dense, per-slot append path
    ("mixtral_8x22b", (20, 11, 18, 3)),     # MoE + window=16 ring: prompts
])                                          # longer than the window wrap it
def test_continuous_greedy_parity_staggered(arch, lens):
    """N staggered mixed-length greedy requests through the slot pool must
    equal N sequential lockstep generate calls, token for token."""
    cfg, params = _model_params(arch)
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in lens]
    gens = [4, 2, 5, 3]

    ref = ServeEngine(cfg, params, ServeConfig(max_len=MAX_LEN, temperature=0.0))
    expected = [np.asarray(ref.generate(jnp.asarray(p)[None], g)[0])[0].tolist()
                for p, g in zip(prompts, gens)]

    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN))
    # staggered arrivals: two up front, the rest land mid-decode
    u0 = eng.submit(prompts[0], gens[0])
    u1 = eng.submit(prompts[1], gens[1])
    eng.step()
    u2 = eng.submit(prompts[2], gens[2])
    eng.step()
    u3 = eng.submit(prompts[3], gens[3])
    done = eng.run()
    assert [done[u] for u in (u0, u1, u2, u3)] == expected


def test_continuous_streaming_events_and_slot_reuse():
    cfg, params = _model_params()
    events = []
    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=1, max_len=MAX_LEN),
        on_token=events.append)
    prompts = [RNG.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
               for _ in range(3)]
    uids = [eng.submit(p, 2) for p in prompts]
    done = eng.run()
    # one slot serves three requests back to back
    assert sorted(done) == sorted(uids)
    assert all(len(v) == 2 for v in done.values())
    # streamed events reconstruct the outputs, in order, with finish flags
    for uid in uids:
        toks = [e.token for e in events if e.uid == uid]
        idxs = [e.index for e in events if e.uid == uid]
        fins = [e.finished for e in events if e.uid == uid]
        assert toks == done[uid]
        assert idxs == [0, 1]
        assert fins == [False, True]


def test_continuous_eos_stops_early():
    cfg, params = _model_params()
    prompt = RNG.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    ref = ServeEngine(cfg, params, ServeConfig(max_len=MAX_LEN, temperature=0.0))
    first = int(np.asarray(ref.generate(jnp.asarray(prompt)[None], 1)[0])[0, 0])

    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN))
    uid = eng.submit(prompt, 30, eos_id=first)  # greedy hits EOS immediately
    done = eng.run()
    assert done[uid] == [first]


def test_continuous_backpressure_more_requests_than_slots():
    cfg, params = _model_params()
    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN))
    uids = [eng.submit(RNG.integers(0, cfg.vocab_size, (3 + i,)), 2)
            for i in range(5)]
    while not eng.scheduler.done():
        eng.step()
        assert len(eng.scheduler.active_slots) <= 2  # pool never oversubscribes
    assert sorted(eng.scheduler.finished) == sorted(uids)
    assert all(len(v) == 2 for v in eng.scheduler.finished.values())


def test_continuous_sampling_independent_of_cotenants():
    """Per-request PRNG streams: a sampled request draws the same tokens
    whether it runs alone or packed with co-tenants."""
    cfg, params = _model_params()
    prompt = RNG.integers(0, cfg.vocab_size, (5,)).astype(np.int32)

    solo = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN, temperature=1.0))
    u_solo = solo.submit(prompt, 3)
    toks_solo = solo.run()[u_solo]

    packed = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN, temperature=1.0))
    u_same = packed.submit(prompt, 3)  # same uid 0 -> same request stream
    packed.submit(RNG.integers(0, cfg.vocab_size, (9,)), 4)
    assert packed.run()[u_same] == toks_solo


def test_continuous_rejects_non_attention_families():
    cfg, params = _model_params("mamba2_130m")
    with pytest.raises(ValueError, match="attention-family"):
        ContinuousBatchingEngine(cfg, params, ContinuousConfig(num_slots=2))


def test_continuous_vlm_mrope_parity():
    """Per-slot 'pos' counters diverge from 'len' for VLM (M-RoPE restarts
    after the patch grid) — the pool must track both."""
    cfg, params = _model_params("qwen2_vl_7b")
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32) for n in (5, 9)]
    pe = [RNG.standard_normal((1, cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
          for _ in prompts]
    gens = [3, 2]

    ref = ServeEngine(cfg, params, ServeConfig(max_len=MAX_LEN, temperature=0.0))
    expected = [
        np.asarray(ref.generate(jnp.asarray(p)[None], g,
                                patch_embeds=jnp.asarray(e))[0])[0].tolist()
        for p, g, e in zip(prompts, gens, pe)]

    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN))
    uids = [eng.submit(p, g, patch_embeds=e)
            for p, g, e in zip(prompts, gens, pe)]
    done = eng.run()
    assert [done[u] for u in uids] == expected


def test_continuous_overflow_rejected_at_submit():
    """A request that cannot fit its whole generation in the slot cache is
    rejected up front (silent K/V drops would corrupt output)."""
    cfg, params = _model_params()
    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=1, max_len=16))
    with pytest.raises(ValueError, match="cache rows"):
        eng.submit(RNG.integers(0, cfg.vocab_size, (12,)), 10)
    # prompt 12 + 5 new tokens writes 12 + 4 rows = exactly max_len: fits
    eng.submit(RNG.integers(0, cfg.vocab_size, (12,)), 5)
    assert all(len(v) == 5 for v in eng.run().values())


def test_run_max_ticks_allows_exact_drain():
    cfg, params = _model_params()
    eng = ContinuousBatchingEngine(
        cfg, params, ContinuousConfig(num_slots=1, max_len=16))
    eng.submit(RNG.integers(0, cfg.vocab_size, (4,)), 1)
    done = eng.run(max_ticks=1)  # finishes on tick 1 -> must not raise
    assert len(done) == 1
