"""Kernel sweep: crossbar MatMul engine model vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.kernels.crossbar_matmul.ref import (
    CrossbarSpec,
    crossbar_matmul_ref,
    exact_matmul_ref,
)

RNG = np.random.default_rng(5)


def crossbar_matmul_op(x, w, *, spec=None, ranging="calibrated", block_m=128):
    """Dispatch-layer call the retired ``ops.py`` shim used to wrap."""
    kw = {"crossbar": spec} if spec is not None else {}
    return ops.matmul(x, w, ops.MatmulSpec(
        impl="hwmodel", ranging=ranging, block_m=block_m, **kw
    ))


@pytest.mark.parametrize("mkn", [(16, 128, 128), (7, 300, 190), (64, 256, 384), (1, 128, 64)])
def test_kernel_bit_exact_vs_ref(mkn):
    m, k, n = mkn
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k, n)) * 0.05, jnp.float32)
    ref = crossbar_matmul_ref(x, w)
    out = crossbar_matmul_op(x, w, block_m=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_calibrated_adc_error_reasonable():
    x = jnp.asarray(RNG.normal(size=(32, 256)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(256, 256)) * 0.05, jnp.float32)
    out = crossbar_matmul_op(x, w)
    exact = exact_matmul_ref(x, w)
    rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
    assert rel < 0.12  # 5-bit ADC, calibrated ranging


def test_fullscale_ranging_much_worse():
    x = jnp.asarray(RNG.normal(size=(16, 256)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(256, 128)) * 0.05, jnp.float32)
    exact = exact_matmul_ref(x, w)
    cal = crossbar_matmul_op(x, w, ranging="calibrated")
    fs = crossbar_matmul_op(x, w, ranging="fullscale")
    e_cal = float(jnp.linalg.norm(cal - exact))
    e_fs = float(jnp.linalg.norm(fs - exact))
    assert e_fs > 3 * e_cal  # worst-case ranging wastes the 5-bit ADC


def test_more_adc_bits_less_error():
    x = jnp.asarray(RNG.normal(size=(16, 256)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(256, 128)) * 0.05, jnp.float32)
    exact = exact_matmul_ref(x, w)
    errs = []
    for bits in (3, 5, 7):
        spec = CrossbarSpec(adc_bits=bits)
        out = crossbar_matmul_ref(x, w, spec)
        errs.append(float(jnp.linalg.norm(out - exact)))
    assert errs[0] > errs[1] > errs[2]
