"""Multi-device tests via subprocess (8 fake CPU devices) — the device-count
flag must never leak into this process."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_worker(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    return r.stdout


def test_sharded_train_matches_single_device():
    out = run_worker("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh
        from repro.train.loop import run_train, LoopConfig
        from repro.train.step import TrainConfig
        tc = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
        lc = LoopConfig(num_steps=6, batch=8, seq_len=32, log_every=100)
        cfg = get_smoke_config("granite_8b")
        a = run_train(cfg, tc, lc, log_fn=lambda *_: None)
        mesh = make_mesh((4, 2), ("data", "model"))
        b = run_train(cfg, tc, lc, mesh=mesh, log_fn=lambda *_: None)
        la, lb = a["history"][-1]["loss"], b["history"][-1]["loss"]
        print("PARITY", la, lb)
        assert abs(la - lb) < 5e-3, (la, lb)
    """)
    assert "PARITY" in out


def test_compressed_allreduce_and_error_feedback():
    out = run_worker("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.collectives import compressed_grad_allreduce, init_error_state
        mesh = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)}
        err = init_error_state(g)
        mean, new_err = compressed_grad_allreduce(g, err, mesh, axis="data")
        rel = float(jnp.linalg.norm(mean["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
        assert rel < 0.01, rel
        rec = float(jnp.max(jnp.abs(mean["w"] + new_err["w"] - g["w"])))
        assert rec < 1e-6, rec  # error feedback reconstructs exactly
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_worker("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.pipeline_parallel import pipeline_apply
        mesh = make_mesh((8,), ("stage",))
        rng = np.random.default_rng(0)
        S, M, mb, d = 8, 4, 2, 16
        Ws = jnp.asarray(rng.normal(size=(S, d, d)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)
        out = pipeline_apply(lambda h, W: jnp.tanh(h @ W), Ws, x, mesh, axis="stage")
        ref = x
        for i in range(S): ref = jnp.tanh(ref @ Ws[i])
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        print("OK")
    """)
    assert "OK" in out


def test_elastic_restore_on_smaller_mesh():
    """Save sharded on 8 devices, restore onto a 4-device mesh (elastic)."""
    out = run_worker("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.checkpoint import checkpointer
        from repro.distributed.elastic import plan_mesh, reshard_plan
        from repro.distributed.sharding import DEFAULT_RULES
        from repro.models.registry import build_model
        from repro.models.param import materialize
        from repro.train.state import init_state, state_specs

        cfg = get_smoke_config("granite_8b")
        model = build_model(cfg)
        specs = state_specs(model.param_specs())
        mesh8 = plan_mesh(8, model_parallel=2)
        sh8 = reshard_plan(specs, DEFAULT_RULES, mesh8)
        state = init_state(model.param_specs(), jax.random.PRNGKey(0))
        state = jax.device_put(state, sh8)
        with tempfile.TemporaryDirectory() as d:
            checkpointer.save(d, 1, state)
            mesh4 = plan_mesh(4, model_parallel=2)
            sh4 = reshard_plan(specs, DEFAULT_RULES, mesh4)
            template = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                specs, is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"))
            restored, step = checkpointer.restore(d, template, shardings=sh4)
            w0 = jax.device_get(state["params"]["final_norm"]["scale"])
            w1 = jax.device_get(restored["params"]["final_norm"]["scale"])
            np.testing.assert_array_equal(w0, w1)
        print("OK elastic", mesh8.shape, "->", mesh4.shape)
    """)
    assert "OK elastic" in out


def test_ep_moe_sharded_forward():
    """Expert-parallel MoE runs under a mesh with experts sharded."""
    out = run_worker("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_mesh
        from repro.distributed.sharding import DEFAULT_RULES, param_shardings, use_mesh_rules
        from repro.models.registry import build_model
        from repro.models.param import materialize
        import dataclasses
        cfg = dataclasses.replace(get_smoke_config("granite_moe_1b_a400m"), moe_style="ep")
        model = build_model(cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        params = materialize(model.param_specs(), jax.random.PRNGKey(0))
        sh = param_shardings(model.param_specs(), DEFAULT_RULES, mesh)
        params = jax.device_put(params, sh)
        toks = jnp.ones((4, 16), jnp.int32)
        with use_mesh_rules(mesh, DEFAULT_RULES):
            logits = jax.jit(model.forward)(params, toks)
        assert bool(jnp.all(jnp.isfinite(logits)))
        print("OK", logits.shape)
    """)
    assert "OK" in out
