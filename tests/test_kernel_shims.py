"""kernels/*/ops.py deprecation shims: warn exactly once per process and
dispatch to the same result as the registry path they wrap."""

import warnings

import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as kernels
from repro import ops

RNG = np.random.default_rng(5)


@pytest.fixture
def fresh_warnings(monkeypatch):
    """Reset the once-per-process guard: earlier tests (the kernel suites
    call the shims heavily) may already have burned the single warning."""
    monkeypatch.setattr(kernels, "_SHIM_WARNED", set())


def test_star_softmax_shim_warns_once_and_matches(fresh_warnings):
    from repro.kernels.star_softmax.ops import star_softmax_op

    x = jnp.asarray(RNG.normal(size=(4, 64)) * 3, jnp.float32)
    with pytest.warns(DeprecationWarning, match="star_softmax_op is deprecated"):
        out = star_softmax_op(x)
    want = ops.softmax(x, ops.SoftmaxSpec(impl="pallas", kind="star"))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    # second call: silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        star_softmax_op(x)


def test_flash_star_shim_warns_once_and_matches(fresh_warnings):
    from repro.kernels.flash_star.ops import flash_star_op

    q = jnp.asarray(RNG.normal(size=(1, 8, 2, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 8, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 8, 2, 16)), jnp.float32)
    with pytest.warns(DeprecationWarning, match="flash_star_op is deprecated"):
        out = flash_star_op(q, k, v, causal=True, block_q=8, block_k=8)
    want = ops.attention(
        q, k, v, ops.AttentionSpec(impl="pallas", causal=True, block_q=8, block_k=8)
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        flash_star_op(q, k, v, causal=True, block_q=8, block_k=8)


def test_crossbar_shim_warns_once_and_matches(fresh_warnings):
    from repro.kernels.crossbar_matmul.ops import crossbar_matmul_op

    x = jnp.asarray(RNG.normal(size=(8, 32)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(32, 16)) * 0.1, jnp.float32)
    with pytest.warns(DeprecationWarning, match="crossbar_matmul_op is deprecated"):
        out = crossbar_matmul_op(x, w)
    want = ops.matmul(x, w, ops.MatmulSpec(impl="hwmodel"))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        crossbar_matmul_op(x, w)


def test_ssd_scan_shim_warns_once_and_matches(fresh_warnings):
    from repro.kernels.ssd_scan.ops import ssd_scan_op

    xdt = jnp.asarray(RNG.normal(size=(1, 32, 2, 8)), jnp.float32)
    a = -jnp.abs(jnp.asarray(RNG.normal(size=(1, 32, 2)) * 0.1, jnp.float32))
    bm = jnp.asarray(RNG.normal(size=(1, 32, 8)) * 0.3, jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(1, 32, 8)) * 0.3, jnp.float32)
    with pytest.warns(DeprecationWarning, match="ssd_scan_op is deprecated"):
        y, h = ssd_scan_op(xdt, a, bm, cm, chunk=16)
    y2, h2 = ops.ssd_scan(xdt, a, bm, cm, ops.ScanSpec(impl="pallas", chunk=16))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(h), np.asarray(h2))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ssd_scan_op(xdt, a, bm, cm, chunk=16)


def test_each_shim_warns_independently(fresh_warnings):
    """The once-guard is per shim, not global: using one shim must not
    swallow another's warning."""
    from repro.kernels.crossbar_matmul.ops import crossbar_matmul_op
    from repro.kernels.star_softmax.ops import star_softmax_op

    x = jnp.asarray(RNG.normal(size=(2, 32)), jnp.float32)
    with pytest.warns(DeprecationWarning, match="star_softmax_op"):
        star_softmax_op(x)
    w = jnp.asarray(RNG.normal(size=(32, 8)) * 0.1, jnp.float32)
    with pytest.warns(DeprecationWarning, match="crossbar_matmul_op"):
        crossbar_matmul_op(x, w)
