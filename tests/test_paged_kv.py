"""Paged KV-cache subsystem: block-pool allocator, engine parity with the
dense slot pool, preemption policy, and block reuse.

The core claim (DESIGN.md §8): greedy decode through the paged pool is
token-identical to the dense per-slot path — block tables change *where*
KV rows live, never *what* attention computes — while memory tracks live
tokens instead of ``num_slots * max_len``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.configs import get_smoke_config
from repro.models.param import materialize
from repro.models.registry import build_model
from repro.serve.engine import (
    ContinuousBatchingEngine,
    ContinuousConfig,
    ServeConfig,
    ServeEngine,
)
from repro.serve.paged import SCRATCH_BLOCK, BlockPool, PoolExhausted

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)
MAX_LEN = 40


# ---------------------------------------------------------------------------
# BlockPool (host allocator)


def test_pool_validation_and_capacity():
    with pytest.raises(ValueError):
        BlockPool(1, 4)  # needs scratch + at least one usable block
    with pytest.raises(ValueError):
        BlockPool(8, 0)
    pool = BlockPool(9, 4)
    assert pool.usable_blocks == 8 and pool.free_blocks == 8
    assert pool.blocks_for_tokens(1) == 1
    assert pool.blocks_for_tokens(4) == 1
    assert pool.blocks_for_tokens(5) == 2


def test_pool_allocate_append_release_roundtrip():
    pool = BlockPool(5, 4)  # 4 usable
    t0 = pool.allocate(0, 2)
    assert len(t0) == 2 and SCRATCH_BLOCK not in t0
    assert pool.used_blocks == 2
    b = pool.append(0)
    assert pool.table(0) == t0 + [b]
    t1 = pool.allocate(1, 1)
    assert set(t1).isdisjoint(pool.table(0))
    with pytest.raises(PoolExhausted):
        pool.allocate(2, 1)  # 4 of 4 in use
    freed = pool.release(0)
    assert sorted(freed) == sorted(t0 + [b])
    assert pool.free_blocks == 3
    # released blocks are reusable immediately
    assert len(pool.allocate(2, 3)) == 3


def test_pool_exhaustion_message_is_actionable():
    pool = BlockPool(3, 4)
    pool.allocate(0, 2)
    with pytest.raises(PoolExhausted, match="needs 1 blocks|exhausted"):
        pool.allocate(1, 1)
    with pytest.raises(PoolExhausted, match="exhausted"):
        pool.append(0)


def test_pool_copy_on_fork_refcounts():
    pool = BlockPool(8, 4)
    parent = pool.allocate(0, 3)
    child = pool.fork(0, 1)
    assert child == parent
    assert pool.used_blocks == 3  # shared blocks counted once
    assert pool.refcount(parent[-1]) == 2
    # a write to the shared last block must privatize it first
    cow = pool.ensure_writable(1)
    assert cow is not None
    src, dst = cow
    assert src == parent[-1] and dst not in parent
    assert pool.table(1)[-1] == dst and pool.table(0)[-1] == src
    assert pool.refcount(src) == 1 and pool.refcount(dst) == 1
    # exclusive table: no copy needed
    assert pool.ensure_writable(0) is None
    # releasing the parent keeps the shared prefix alive for the child
    freed = pool.release(0)
    assert freed == [src]  # prefix blocks still referenced by the child
    assert pool.used_blocks == 3  # 2 shared prefix + child's private last
    assert sorted(pool.release(1)) == sorted(parent[:-1] + [dst])
    assert pool.free_blocks == 7


# ---------------------------------------------------------------------------
# engine parity vs the dense slot pool


def _model_params(arch="granite_8b"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    return cfg, materialize(model.param_specs(), KEY)


def _expected(cfg, params, prompts, gens, frontends=None):
    ref = ServeEngine(cfg, params, ServeConfig(max_len=MAX_LEN, temperature=0.0))
    fes = frontends or [{} for _ in prompts]
    return [
        np.asarray(ref.generate(
            jnp.asarray(p)[None], g,
            **{k: jnp.asarray(v) for k, v in fe.items()})[0])[0].tolist()
        for p, g, fe in zip(prompts, gens, fes)
    ]


@pytest.mark.parametrize("arch,lens", [
    ("granite_8b", (5, 11, 8, 3)),           # dense append path
    ("granite_moe_1b_a400m", (5, 11, 8, 3)),  # MoE router in the loop
    ("mixtral_8x22b", (20, 11, 18, 3)),       # window=16 ring: prompts wrap
])
def test_paged_greedy_parity(arch, lens):
    cfg, params = _model_params(arch)
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in lens]
    gens = [4, 2, 5, 3]
    expected = _expected(cfg, params, prompts, gens)
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=2, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4))
    uids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    done = eng.run()
    assert [done[u] for u in uids] == expected


def test_paged_vlm_mrope_parity():
    cfg, params = _model_params("qwen2_vl_7b")
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 9)]
    pe = [RNG.standard_normal((1, cfg.num_patches, cfg.frontend_dim))
          .astype(np.float32) for _ in prompts]
    gens = [3, 2]
    expected = _expected(cfg, params, prompts, gens,
                         [{"patch_embeds": e} for e in pe])
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=2, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4))
    uids = [eng.submit(p, g, patch_embeds=e)
            for p, g, e in zip(prompts, gens, pe)]
    done = eng.run()
    assert [done[u] for u in uids] == expected


def test_ops_use_paged_flips_engine_layout():
    """ops.use(attention="paged") alone must flip the serve stack."""
    cfg, params = _model_params()
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 8)]
    expected = _expected(cfg, params, prompts, [3, 2])
    with ops.use(attention="paged"):
        eng = ContinuousBatchingEngine(
            cfg, params, ContinuousConfig(num_slots=2, max_len=MAX_LEN))
        assert eng.kv_layout == "paged"
        uids = [eng.submit(p, g) for p, g in zip(prompts, [3, 2])]
        done = eng.run()
    assert [done[u] for u in uids] == expected


def test_paged_memory_tracks_live_tokens():
    """Peak paged KV bytes stay strictly below the dense pool's buffer."""
    cfg, params = _model_params()
    prompts = [RNG.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(3)]
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=2, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4))
    for p in prompts:
        eng.submit(p, 3)
    eng.run()
    st = eng.kv_stats()
    assert st["used_blocks"] == 0  # everything released on retire
    assert 0 < st["peak_kv_bytes"] < st["kv_bytes_capacity"]
    # dense equivalent capacity = num_slots * cache_len rows (kv_row_bytes
    # already counts both K and V)
    dense_bytes = eng.cb.num_slots * eng._cache_t * eng.kv_row_bytes()
    assert st["peak_kv_bytes"] < dense_bytes


# ---------------------------------------------------------------------------
# scheduler / allocator edge cases (ISSUE satellites)


def test_request_longer_than_pool_rejected_actionably():
    cfg, params = _model_params()
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=1, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4,
                         kv_pool_blocks=3))
    with pytest.raises(ValueError, match="KV blocks.*kv_pool_blocks"):
        eng.submit(RNG.integers(0, cfg.vocab_size, (10,)), 8)
    # a fitting request still admits
    uid = eng.submit(RNG.integers(0, cfg.vocab_size, (6,)), 3)
    assert len(eng.run()[uid]) == 3


def test_pool_exhaustion_preempts_lowest_priority_first():
    """When the pool runs dry, the latest-admitted (highest-uid) active
    request is evicted and requeued — earlier requests never yield to
    later ones — and every preempted request still completes with output
    identical to an uncontended run."""
    cfg, params = _model_params()
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (7, 9, 5)]
    gens = [8, 7, 6]
    expected = _expected(cfg, params, prompts, gens)

    # pool of 6 usable blocks at block 4: three slots cannot co-reside at
    # full depth, so decode-time appends must preempt
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=3, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4,
                         kv_pool_blocks=6))
    preempted = []
    orig = eng._preempt

    def spy(slot):
        preempted.append(slot.request.uid)
        orig(slot)

    eng._preempt = spy
    uids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    done = eng.run()
    assert [done[u] for u in uids] == expected
    assert eng.preemptions > 0
    assert all(u in uids for u in preempted)
    # FIFO priority: the oldest request (uid 0) is never evicted while
    # younger co-tenants hold blocks — victims come from the back of the
    # line
    assert uids[0] not in preempted


def test_preemption_victim_ordering_is_latest_first():
    cfg, params = _model_params()
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=3, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4,
                         kv_pool_blocks=6))
    victims = []
    orig = eng._preempt
    eng._preempt = lambda s: (victims.append(s.request.uid), orig(s))[1]
    for n, g in zip((7, 9, 5), (8, 7, 6)):
        eng.submit(RNG.integers(0, cfg.vocab_size, (n,)), g)
    eng.run()
    assert victims, "expected pool pressure to force at least one preemption"
    # whenever a victim is chosen, it is never uid 0 (the oldest request
    # keeps its blocks to completion under FIFO priority)
    assert 0 not in victims


def test_block_table_reuse_after_retire_no_stale_reads():
    """A slot's blocks return to the pool on retire; the next request
    recycles them.  Its output must match an uncontended run — i.e. no
    stale KV rows from the previous owner leak through the gather."""
    cfg, params = _model_params()
    prompts = [RNG.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (11, 4, 9, 6, 13)]
    gens = [3, 5, 2, 4, 3]
    expected = _expected(cfg, params, prompts, gens)
    # one slot: every request reuses the same recycled blocks back to back
    eng = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=1, max_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=4,
                         kv_pool_blocks=5))
    uids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    done = eng.run()
    assert [done[u] for u in uids] == expected
    assert eng.block_pool.used_blocks == 0


def test_paged_sampling_streams_survive_preemption():
    """Per-request PRNG streams are indexed by absolute generation index,
    so a preempted+resumed sampled request draws the same tokens as an
    uncontended run."""
    cfg, params = _model_params()
    prompt = RNG.integers(0, cfg.vocab_size, (5,)).astype(np.int32)

    solo = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=2, max_len=MAX_LEN, temperature=1.0,
                         kv_layout="paged", kv_block_size=4))
    u = solo.submit(prompt, 6)
    toks_solo = solo.run()[u]

    packed = ContinuousBatchingEngine(
        cfg, params,
        ContinuousConfig(num_slots=2, max_len=MAX_LEN, temperature=1.0,
                         kv_layout="paged", kv_block_size=4,
                         kv_pool_blocks=5))
    u_same = packed.submit(prompt, 6)  # same uid 0 -> same request stream
    packed.submit(RNG.integers(0, cfg.vocab_size, (9,)), 5)
    assert packed.run()[u_same] == toks_solo


def test_scheduler_preempt_requeues_at_front():
    from repro.serve.scheduler import SlotScheduler

    sched = SlotScheduler(1)
    u0 = sched.submit(np.arange(3), 5)
    u1 = sched.submit(np.arange(4), 5)
    (slot,) = sched.admit()
    sched.record_token(slot, 7)
    sched.record_token(slot, 8)
    req = sched.preempt(slot)
    assert req.uid == u0 and req.generated_prefix == [7, 8]
    # the preempted request is first in line again, ahead of u1
    assert [r.uid for r in sched.pending] == [u0, u1]
    (slot,) = sched.admit()
    assert slot.request.uid == u0
    # budget counts the prefix: 3 more tokens finish the request
    assert sched.record_token(slot, 9) is False
    assert sched.record_token(slot, 10) is False
    assert sched.record_token(slot, 11) is True
    sched.retire(slot)
    assert sched.finished[u0] == [7, 8, 9, 10, 11]
