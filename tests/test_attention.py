import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import (
    EXACT_SOFTMAX,
    STAR_SOFTMAX,
    SoftmaxConfig,
    attention,
    blocked_attention,
)
from repro.core.fixedpoint import FORMAT_MRPC

RNG = np.random.default_rng(42)


def qkv(b=2, tq=33, tk=70, hq=8, hkv=2, d=32):
    q = jnp.asarray(RNG.normal(size=(b, tq, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, tk, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, tk, hkv, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("softmax", [EXACT_SOFTMAX, STAR_SOFTMAX])
@pytest.mark.parametrize("block", [16, 32, 512])
def test_blocked_equals_full(softmax, block):
    q, k, v = qkv()
    full = attention(q, k, v, softmax=softmax, causal=True, q_offset=37)
    blk = blocked_attention(
        q, k, v, softmax=softmax, causal=True, q_offset=37, block_size=block
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(blk), atol=3e-6)


def test_gqa_mqa_shapes():
    for hq, hkv in [(8, 8), (8, 2), (4, 1)]:
        q, k, v = qkv(hq=hq, hkv=hkv)
        out = attention(q, k, v, softmax=STAR_SOFTMAX, causal=True, q_offset=37)
        assert out.shape == q.shape


def test_sliding_window_and_ragged():
    q, k, v = qkv()
    kvl = jnp.asarray([50, 70])
    a = attention(q, k, v, softmax=STAR_SOFTMAX, causal=True, q_offset=37,
                  sliding_window=24, kv_valid_len=kvl)
    b = blocked_attention(q, k, v, softmax=STAR_SOFTMAX, causal=True, q_offset=37,
                          sliding_window=24, kv_valid_len=kvl, block_size=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-6)


def test_sliding_window_masks_far_context():
    """With window w, positions further than w back must not influence out."""
    q, k, v = qkv(tq=1, tk=64)
    a1 = attention(q, k, v, softmax=EXACT_SOFTMAX, causal=True, q_offset=63,
                   sliding_window=8)
    k2 = k.at[:, :50].set(RNG.normal(size=(2, 50, 2, 32)))  # outside window
    a2 = attention(q, k2, v, softmax=EXACT_SOFTMAX, causal=True, q_offset=63,
                   sliding_window=8)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=1e-6)


def test_star_close_to_exact():
    q, k, v = qkv()
    a = attention(q, k, v, softmax=STAR_SOFTMAX, causal=True, q_offset=37)
    e = attention(q, k, v, softmax=EXACT_SOFTMAX, causal=True, q_offset=37)
    # attention output error ~ softmax quantization error x |V|
    assert float(jnp.max(jnp.abs(a - e))) < 0.3
    # 9-bit tighter than 8-bit
    a9 = attention(q, k, v, softmax=SoftmaxConfig(kind="star", fmt=FORMAT_MRPC),
                   causal=True, q_offset=37)
    assert float(jnp.mean(jnp.abs(a9 - e))) <= float(jnp.mean(jnp.abs(a - e))) + 1e-6


def test_decode_step_shape():
    q, k, v = qkv(tq=1, tk=80)
    out = attention(q, k, v, softmax=STAR_SOFTMAX, causal=True, q_offset=79)
    assert out.shape == (2, 1, 8, 32)


def test_ste_attention_grads():
    q, k, v = qkv(b=1, tq=16, tk=16)
    cfg = SoftmaxConfig(kind="star_ste")
    g = jax.grad(lambda q: jnp.sum(attention(q, k, v, softmax=cfg, causal=True) ** 2))(q)
    assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.linalg.norm(g)) > 0


def test_unroll_context_parity():
    from repro.core.scan_ctl import unroll_scans

    q, k, v = qkv()
    a = blocked_attention(q, k, v, softmax=STAR_SOFTMAX, causal=True,
                          q_offset=37, block_size=16)
    with unroll_scans():
        b = blocked_attention(q, k, v, softmax=STAR_SOFTMAX, causal=True,
                              q_offset=37, block_size=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
